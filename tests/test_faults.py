"""Fault-tolerant query execution: deterministic fault injection, lineage
recovery, speculation, and the chaos property suite.

The tentpole behaviors under test: a seeded ``FaultPlan`` is a reproducible
fixture (crash-before/after, straggle, stage loss); a lost shuffle stage
triggers bounded recursive recompute of only the missing partitions'
producers; stragglers get speculative backups, first completion wins; the
same plan replayed through simulator and runtime yields identical decision
sequences and recovery stage sets; and under *random* fault schedules every
query either completes oracle-equal or raises a typed error — never hangs,
never leaks slots or store bytes.
"""

import threading
import time

import numpy as np
import pytest

from tests._hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.analytics import (
    QueryStrategy,
    build_query_workflow,
    execute_query_runtime,
    make_cluster,
    plan_query_tasks,
    sim_fault_models,
    stages_for_run,
    synth_query_tables,
)
from repro.core.controllers import GlobalController, PrivateController
from repro.core.decisions import recovery_node, should_speculate
from repro.runtime import (
    CrashFault,
    FairShareGate,
    FaultInjector,
    FaultPlan,
    InlineInvoker,
    Invocation,
    InvocationError,
    MetricsSink,
    QuotaExceededError,
    RecoveryError,
    Runtime,
    RuntimeStage,
    ShuffleStore,
    SpeculationPolicy,
    StageLossFault,
    StageLostError,
    StragglerFault,
    ThreadPoolInvoker,
    expected_recovery,
)

STRATEGIES = ("static_merge", "static_hash", "dynamic", "dynamic_fig6")

# typed outcomes a faulty run may legitimately surface (the contract: a
# query completes oracle-equal or raises one of these — nothing silent)
TYPED_ERRORS = (RecoveryError, InvocationError, StageLostError,
                QuotaExceededError)


@pytest.fixture(scope="module")
def tables():
    return synth_query_tables(4096, 512, seed=1)


def _run_with_plan(tables, plan, strat="static_merge", quota=None,
                   recovery="lineage", max_recoveries=8):
    fd, dd, ref = tables
    gc = GlobalController({n: 8 for n in range(4)})
    rt = Runtime(gc)
    if quota is not None:
        rt.store.set_quota("query", quota)
    inj = FaultInjector(plan).install(rt)
    got, _ = execute_query_runtime(fd, dd, QueryStrategy(strat), runtime=rt,
                                   recovery=recovery,
                                   max_recoveries=max_recoveries)
    np.testing.assert_allclose(got, ref, atol=1e-3)
    assert sum(gc.used.values()) == 0
    return rt, inj


# -- crash injection: before-commit and after-write --------------------------------


def test_crash_before_commit_retried_with_no_writes(tables):
    plan = FaultPlan(crashes=[CrashFault("scan_fact", index=1,
                                         when="before")])
    rt, inj = _run_with_plan(tables, plan)
    assert ("crash-before", "query/scan_fact/1") in inj.injected
    recs = [r for r in rt.metrics.records if r.name == "query/scan_fact/1"]
    assert [r.status for r in recs] == ["crashed", "ok"]
    assert recs[0].attempt == 0 and recs[1].attempt == 1
    assert recs[0].bytes_out == 0          # crash-before-commit wrote nothing


def test_crash_after_write_retry_overwrites_not_duplicates(tables):
    """Crash-after-write leaves the dead attempt's outputs in the store; the
    retry overwrites them under the same writer label (never duplicates),
    so the result stays oracle-equal."""
    plan = FaultPlan(crashes=[CrashFault("join", index=0, when="after")])
    rt, inj = _run_with_plan(tables, plan)
    assert ("crash-after", "query/join/0") in inj.injected
    recs = [r for r in rt.metrics.records if r.name == "query/join/0"]
    assert [r.status for r in recs] == ["crashed", "ok"]


def test_repeated_crashes_exhaust_attempts_with_typed_error(tables):
    fd, dd, _ = tables
    plan = FaultPlan(crashes=[CrashFault("final_agg", when="before",
                                         attempt=a, times=1)
                              for a in range(5)])
    gc = GlobalController({n: 8 for n in range(4)})
    rt = Runtime(gc)
    FaultInjector(plan).install(rt)
    with pytest.raises(InvocationError, match="crashed"):
        execute_query_runtime(fd, dd, QueryStrategy("static_hash"),
                              runtime=rt)
    assert sum(gc.used.values()) == 0      # every crashed claim released


# -- stage loss + lineage recovery -------------------------------------------------


def test_lost_stage_recovers_recursively_through_gcd_inputs(tables):
    """Losing a 'joined' partition after the join's bucket inputs were
    GC-reclaimed forces recursive recompute: shuffle writes first (their
    scan inputs are resident), then the join, then the consumer retries."""
    plan = FaultPlan(losses=[StageLossFault("joined", partitions=(0,),
                                            on_read=1)])
    rt, _ = _run_with_plan(tables, plan, strat="static_merge")
    assert len(rt.recoveries) == 1
    ev = rt.recoveries[0]
    assert ev.lost_stage == "joined" and ev.partitions == (0,)
    # bottom-up: the GC'd exchange inputs are recomputed before the join
    assert ev.recovered == ("dim_buckets", "fact_buckets", "joined")
    assert ev.invocations < 15             # far less than the whole query


def test_quota_sealed_inputs_make_recovery_shallow(tables):
    """Under a store quota, consumed inputs are sealed (readable) instead of
    dropped — so healing the same loss re-executes only the lost
    partition's join producer, nothing upstream."""
    plan = FaultPlan(losses=[StageLossFault("joined", partitions=(0,),
                                            on_read=1)])
    rt, _ = _run_with_plan(tables, plan, strat="static_merge",
                           quota=1 << 30)
    assert rt.recoveries[0].recovered == ("joined",)
    assert rt.recoveries[0].invocations == 1


def test_lost_base_input_is_unrecoverable_typed_error(tables):
    fd, dd, _ = tables
    plan = FaultPlan(losses=[StageLossFault("input/fact", on_read=1)])
    gc = GlobalController({n: 8 for n in range(4)})
    rt = Runtime(gc)
    FaultInjector(plan).install(rt)
    with pytest.raises(RecoveryError, match="no lineage"):
        execute_query_runtime(fd, dd, QueryStrategy("static_hash"),
                              runtime=rt)
    assert sum(gc.used.values()) == 0


def test_recovery_budget_zero_surfaces_rerun_error(tables):
    fd, dd, _ = tables
    plan = FaultPlan(losses=[StageLossFault("joined", on_read=1)])
    gc = GlobalController({n: 8 for n in range(4)})
    rt = Runtime(gc)
    FaultInjector(plan).install(rt)
    with pytest.raises(RecoveryError):
        execute_query_runtime(fd, dd, QueryStrategy("static_hash"),
                              runtime=rt, recovery="rerun")


def test_recovery_decision_node_can_choose_whole_query_rerun(tables):
    """Failure handling as a decision workflow: a recovery node that deems
    every recompute too expensive forces the rerun path."""
    fd, dd, _ = tables
    plan = FaultPlan(losses=[StageLossFault("joined", on_read=1)])
    gc = GlobalController({n: 8 for n in range(4)})
    rt = Runtime(gc)
    FaultInjector(plan).install(rt)
    node = recovery_node(max_reexec_frac=0.0)
    with pytest.raises(RecoveryError, match="rerun"):
        execute_query_runtime(fd, dd, QueryStrategy("static_hash"),
                              runtime=rt, recovery=node)
    assert node.history and node.history[-1][1].func == "rerun"


def test_acceptance_plan_all_strategies_oracle_equal(tables):
    """The acceptance scenario: >=2 killed invocations, >=1 evicted
    consumed ephemeral stage, >=1 straggled node — all four strategies
    complete oracle-equal with lineage recovery."""
    for strat in STRATEGIES:
        plan = FaultPlan(
            crashes=[CrashFault("scan_fact", index=0, when="before"),
                     CrashFault("join", index=0, when="after")],
            stragglers=[StragglerFault(node=1, delay=0.02, times=2)],
            losses=[StageLossFault("joined", partitions=(0,), on_read=1)])
        rt, inj = _run_with_plan(tables, plan, strat=strat)
        kinds = {k for k, _ in inj.injected}
        assert {"crash-before", "crash-after", "straggle",
                "stage-loss"} <= kinds
        assert rt.recoveries


def test_whole_stage_loss_with_wide_fanout_heals_in_one_round():
    """Regression: a whole-stage loss read partition-by-partition must heal
    all currently-lost partitions in one recovery round, not burn one round
    (and one recovery-plan) per consumer partition."""
    gc = GlobalController({0: 8})
    rt = Runtime(gc)

    def produce(ctx):
        ctx.put(ctx.params["dst"], ctx.params["partition"], FakeTable(10))

    def consume(ctx):
        t = ctx.get(ctx.params["src"], ctx.params["partition"])
        assert t is not None and t.nbytes == 10
        ctx.put(ctx.params["dst"], ctx.params["partition"], FakeTable(5))

    rt.invoker.registry = {"produce": produce, "consume": consume}
    n = 4
    stages = [
        RuntimeStage("producers", [
            Invocation(f"a/producers/{i}", "a", "producers", i, "produce", 0,
                       params={"src": "input", "dst": "data", "partition": i})
            for i in range(n)]),
        RuntimeStage("consumers", [
            Invocation(f"a/consumers/{i}", "a", "consumers", i, "consume", 0,
                       params={"src": "data", "dst": "out", "partition": i})
            for i in range(n)], deps=("producers",)),
    ]
    FaultInjector(FaultPlan(
        losses=[StageLossFault("data", on_read=1)])).install(rt)
    # budget 1 < fan-out: only a full-set heal can succeed
    rt.execute(stages, max_recoveries=1)
    assert len(rt.recoveries) == 1
    assert rt.recoveries[0].partitions == tuple(range(n))
    assert rt.recoveries[0].invocations == n


def test_rerun_on_same_runtime_does_not_duplicate_lineage(tables):
    """Regression: re-registering the same app's stages (whole-query rerun
    on one Runtime) replaces the old lineage — recovery must not re-execute
    every producer twice."""
    fd, dd, ref = tables
    gc = GlobalController({n: 8 for n in range(4)})
    rt = Runtime(gc)
    execute_query_runtime(fd, dd, QueryStrategy("static_merge"), runtime=rt)
    n_first = len(rt.lineage.producers("query", "joined"))
    total_first = rt.lineage.total_invocations("query")
    rt.release("query")
    got, _ = execute_query_runtime(fd, dd, QueryStrategy("static_merge"),
                                   runtime=rt)
    np.testing.assert_allclose(got, ref, atol=1e-3)
    assert len(rt.lineage.producers("query", "joined")) == n_first
    assert rt.lineage.total_invocations("query") == total_first


# -- store semantics under loss ----------------------------------------------------


class FakeTable:
    def __init__(self, nbytes, rows=1):
        self.nbytes, self.num_rows = nbytes, rows

    def concat(self, other):
        return FakeTable(self.nbytes + other.nbytes,
                         self.num_rows + other.num_rows)


def test_lose_stage_tombstones_then_rewrite_heals():
    store = ShuffleStore()
    store.put("a", "s", 0, FakeTable(10), node=0, writer="w0")
    store.put("a", "s", 1, FakeTable(20), node=0, writer="w0")
    freed = store.lose_stage("a", "s", partitions=[0])
    assert freed == 10
    with pytest.raises(StageLostError):
        store.get("a", "s", 0, node=0)
    assert store.get("a", "s", 1, node=0).nbytes == 20   # untouched
    assert store.partitions("a", "s") == [0, 1]          # lost id visible
    store.put("a", "s", 0, FakeTable(15), node=0, writer="w0")   # recompute
    assert store.get("a", "s", 0, node=0).nbytes == 15
    assert store.lost_partitions("a", "s") == set()


def test_reclaimed_ephemeral_stage_reads_as_lost_not_none():
    store = ShuffleStore()
    store.put("a", "s", 0, FakeTable(10), node=0, writer="w")
    assert store.reclaim_stage("a", "s") == 10
    with pytest.raises(StageLostError):
        store.get("a", "s", 0, node=0)
    # intentional teardown clears the tombstones
    store.clear_app("a")
    assert store.get("a", "s", 0, node=0) is None


def test_reclaim_racing_concurrent_get_full_data_or_lost():
    """Satellite: a reader racing reclaim/eviction must observe the full
    stage or a typed loss — never a partial stage, never silent None."""
    for trial in range(20):
        store = ShuffleStore()
        for w, nb in (("w0", 10), ("w1", 20), ("w2", 40)):
            store.put("a", "s", 0, FakeTable(nb), node=0, writer=w)
        seen = []
        stop = threading.Event()

        def reader():
            while not stop.is_set():
                try:
                    t = store.get("a", "s", 0, node=0)
                    seen.append(t.nbytes if t is not None else None)
                except StageLostError:
                    seen.append("lost")
                    return

        th = threading.Thread(target=reader)
        th.start()
        time.sleep(0.0005 * (trial % 5))
        store.reclaim_stage("a", "s")
        stop.set()
        th.join(timeout=10)
        assert not th.is_alive()
        assert set(seen) <= {70, "lost"}, seen


def test_quota_eviction_racing_get_full_data_or_lost():
    store = ShuffleStore(quotas={"a": 100}, quota_timeout=5.0)
    for w, nb in (("w0", 10), ("w1", 20), ("w2", 40)):
        store.put("a", "old", 0, FakeTable(nb), node=0, writer=w)
    store.seal("a", "old")
    seen = []
    stop = threading.Event()

    def reader():
        while not stop.is_set():
            try:
                t = store.get("a", "old", 0, node=0)
                seen.append(t.nbytes if t is not None else None)
            except StageLostError:
                seen.append("lost")
                return

    th = threading.Thread(target=reader)
    th.start()
    store.put("a", "new", 0, FakeTable(80), node=0, writer="w")  # evicts old
    stop.set()
    th.join(timeout=10)
    assert store.evictions and store.evictions[0][:2] == ("a", "old")
    assert set(seen) <= {70, "lost"}, seen


# -- straggler speculation ---------------------------------------------------------


def test_should_speculate_predicate():
    assert not should_speculate([], 10.0)                  # no siblings done
    assert not should_speculate([0.1], 10.0, min_done=2)
    assert should_speculate([0.1, 0.1, 0.1], 0.5, multiple=2.0)
    assert not should_speculate([0.1, 0.1, 0.1], 0.15, multiple=2.0)
    # the floor suppresses microsecond-scale speculation
    assert not should_speculate([1e-4] * 4, 1e-3, multiple=2.0, floor=0.05)


def test_straggler_gets_backup_first_completion_wins(tables):
    fd, dd, ref = tables
    delay = 0.8
    plan = FaultPlan(stragglers=[StragglerFault(node=1, delay=delay,
                                                stage="scan_fact")])
    gc = GlobalController({n: 8 for n in range(4)})
    store, metrics = ShuffleStore(), MetricsSink()
    invoker = ThreadPoolInvoker(
        gc, store, metrics, max_workers=8,
        speculation=SpeculationPolicy(multiple=3.0, floor=0.02,
                                      interval=0.01))
    rt = Runtime(gc, invoker=invoker, store=store, metrics=metrics)
    FaultInjector(plan).install(rt)
    t0 = time.perf_counter()
    got, _ = execute_query_runtime(fd, dd, QueryStrategy("static_hash"),
                                   runtime=rt)
    wall = time.perf_counter() - t0
    np.testing.assert_allclose(got, ref, atol=1e-3)
    assert wall < delay                    # did not wait for the straggler
    specs = [s for s in invoker.speculations
             if s[0].startswith("query/scan_fact/")]
    assert specs
    name, stuck_node, backup_node, _ = specs[0]
    assert stuck_node == 1 and backup_node != 1
    # decision-node history shows the speculation decision workflow fired
    history = invoker.speculation.node.history
    assert any(d.func == "speculate" for _, d in history)
    invoker.drain()                        # join the losing copy
    assert sum(gc.used.values()) == 0      # first-completion-wins, no leak


# -- satellite: registered-function exceptions must not leak slots ------------------


def test_fn_exception_releases_claim_and_gate():
    """Regression: a registered function raising must finish the claim and
    return the FairShareGate token — a leak would deadlock the gate."""
    gc = GlobalController({0: 2, 1: 2})
    gate = FairShareGate(total_slots=4, timeout=2.0)
    store, metrics = ShuffleStore(), MetricsSink()
    invoker = ThreadPoolInvoker(gc, store, metrics, gate=gate)

    def boom(ctx):
        raise RuntimeError("function body exploded")

    invoker.registry = {"boom": boom, "noop": lambda ctx: None}
    invs = [Invocation(f"a/s/{i}", "a", "s", i, "boom", node=i % 2)
            for i in range(4)]
    with pytest.raises(RuntimeError, match="exploded"):
        invoker.run_stage(invs)
    assert sum(gc.used.values()) == 0
    assert all(v == 0 for v in gate.in_use.values())
    errs = [r for r in metrics.records if r.status == "error"]
    assert errs                            # the failure left a record
    # the gate still admits fresh work — no deadlocked accounting
    invoker.run_stage([Invocation("a/s2/0", "a", "s2", 0, "noop", node=0)])
    assert sum(gc.used.values()) == 0


def test_fn_base_exception_releases_claim():
    """Even a BaseException (not an Exception subclass) must not leak the
    controller slot."""

    class Sigkill(BaseException):
        pass

    gc = GlobalController({0: 1})
    invoker = InlineInvoker(gc, ShuffleStore(), MetricsSink())

    def die(ctx):
        raise Sigkill()

    invoker.registry = {"die": die}
    with pytest.raises(Sigkill):
        invoker.run_stage([Invocation("a/s/0", "a", "s", 0, "die", node=0)])
    assert sum(gc.used.values()) == 0


# -- differential: simulator vs runtime under the same seeded plan ------------------


@pytest.mark.parametrize("seed", (3, 11))
def test_seeded_plan_sim_and_runtime_parity(tables, seed):
    """Satellite: the same seeded FaultPlan replayed through simulator and
    runtime yields identical decision sequences and recovery stage sets."""
    fd, dd, ref = tables
    plan = FaultPlan.seeded(seed, stages=("scan_fact", "join"),
                            data_stages=("joined",), nodes=(0, 1),
                            delay=0.01)
    wf = build_query_workflow(QueryStrategy("dynamic_fig6"))

    # runtime plane
    gc = GlobalController({n: 8 for n in range(4)})
    rt = Runtime(gc)
    FaultInjector(plan).install(rt)
    got, _ = execute_query_runtime(fd, dd, QueryStrategy("dynamic_fig6"),
                                   runtime=rt, workflow=wf)
    np.testing.assert_allclose(got, ref, atol=1e-3)
    seq_rt = list(wf.last_run.sequence)
    recovered_rt = [ev.recovered for ev in rt.recoveries
                    if ev.lost_stage == "joined"]

    # simulator plane: same workflow object + matching failure models
    straggle, crash = sim_fault_models(plan)
    gc_sim, sim = make_cluster(4, straggle=straggle, crash_plan=crash)
    pc = PrivateController("query", gc_sim, priority=10)
    plan_query_tasks(sim, pc, fd, dd, QueryStrategy("dynamic_fig6"),
                     workflow=wf)
    seq_sim = list(wf.last_run.sequence)
    out = sim.run()
    assert out["completion"]["query"] > 0
    assert sim.reexecutions == sum(crash.values())

    # identical decision sequences, stage by stage, Decision-equal
    assert seq_rt == seq_sim
    # identical recovery stage sets: the static prediction from the sim
    # plan matches what the runtime actually recomputed
    fl = [(i, n) for i, (n, _) in enumerate(sorted(fd.partitions.items()))]
    dl = [(j, n) for j, (n, _) in enumerate(sorted(dd.partitions.items()))]
    stages = stages_for_run(wf.last_run, "query", fl, dl)
    predicted = tuple(expected_recovery(stages, "joined"))
    for actual in recovered_rt:
        assert actual == predicted


def test_expected_recovery_matches_runtime_for_deep_chain(tables):
    """Static prediction covers the recursive case too (merge path, GC'd
    exchange inputs)."""
    fd, dd, _ = tables
    plan = FaultPlan(losses=[StageLossFault("joined", on_read=1)])
    rt, _ = _run_with_plan(tables, plan, strat="static_merge")
    wf = build_query_workflow(QueryStrategy("static_merge"))
    gc_sim, sim = make_cluster(4)
    pc = PrivateController("query", gc_sim, priority=10)
    plan_query_tasks(sim, pc, fd, dd, QueryStrategy("static_merge"),
                     workflow=wf)
    fl = [(i, n) for i, (n, _) in enumerate(sorted(fd.partitions.items()))]
    dl = [(j, n) for j, (n, _) in enumerate(sorted(dd.partitions.items()))]
    stages = stages_for_run(wf.last_run, "query", fl, dl)
    assert tuple(expected_recovery(stages, "joined")) == \
        rt.recoveries[0].recovered


# -- chaos: hypothesis-driven random fault schedules --------------------------------

PHYS_STAGES = ("scan_fact", "scan_dim", "shuffle_fact", "join",
               "partial_agg", "final_agg")
DATA_STAGES = ("input/fact", "scan_fact", "scan_dim", "fact_buckets",
               "dim_bcast", "joined", "partials", "result")

crash_st = st.builds(
    CrashFault,
    stage=st.sampled_from(PHYS_STAGES),
    index=st.one_of(st.none(), st.integers(0, 3)),
    when=st.sampled_from(("before", "after")),
    attempt=st.integers(0, 1),
    times=st.integers(1, 2))
loss_st = st.builds(
    StageLossFault,
    stage=st.sampled_from(DATA_STAGES),
    partitions=st.one_of(st.none(), st.just((0,))),
    on_read=st.integers(1, 4))
straggle_st = st.builds(
    StragglerFault,
    node=st.integers(0, 3),
    delay=st.floats(0.001, 0.01),
    stage=st.one_of(st.none(), st.sampled_from(PHYS_STAGES)),
    times=st.just(1))
plan_st = st.builds(
    FaultPlan,
    crashes=st.lists(crash_st, max_size=3),
    stragglers=st.lists(straggle_st, max_size=2),
    losses=st.lists(loss_st, max_size=2))


@pytest.fixture(scope="module")
def chaos_tables():
    return synth_query_tables(1024, 128, seed=7)


@settings(deadline=None, max_examples=25)
@given(plan=plan_st, strat=st.sampled_from(STRATEGIES),
       quota=st.booleans())
def test_chaos_random_fault_schedules_complete_or_typed_error(
        chaos_tables, plan, strat, quota):
    """Under arbitrary crash/loss/straggle interleavings the query either
    completes with oracle-equal results or raises a typed error — it never
    hangs, never corrupts results, never leaks slots or store bytes."""
    fd, dd, ref = chaos_tables
    gc = GlobalController({n: 8 for n in range(4)})
    rt = Runtime(gc)
    if quota:
        rt.store.set_quota("query", 1 << 30)
    FaultInjector(plan).install(rt)
    try:
        got, _ = execute_query_runtime(fd, dd, QueryStrategy(strat),
                                       runtime=rt, max_recoveries=4)
    except TYPED_ERRORS:
        pass
    else:
        np.testing.assert_allclose(got, ref, atol=1e-3)
    # invariants hold on every path, success or typed failure
    assert sum(gc.used.values()) == 0                  # no leaked slots
    assert all(v >= 0 for v in rt.store.resident_bytes.values())
    rt.store.set_quota("query", None)
    rt.release("query")
    assert rt.store.app_bytes.get("query", 0) == 0     # no leaked bytes


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_chaos_suite_really_runs_marker():
    """CI marker: the chaos property suite executes (it silently skips on
    bare environments without hypothesis)."""
    assert HAVE_HYPOTHESIS
