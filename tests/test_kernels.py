"""Per-kernel allclose vs the jnp oracles (interpret mode), shape/dtype
sweeps + hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.partition import partition_histogram, partition_scatter

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _qkv(key, b, s, h, hd, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    mk = lambda k: jax.random.normal(k, (b, s, h, hd), dtype)
    return mk(k1), mk(k2), mk(k3)


@pytest.mark.parametrize("b,s,h,hd", [
    (1, 64, 1, 32), (2, 128, 4, 64), (1, 256, 2, 128), (2, 64, 8, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(b, s, h, hd, dtype, causal):
    q, k, v = _qkv(jax.random.PRNGKey(42), b, s, h, hd, dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=32, block_k=32,
                          interpret=True)
    expected = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expected, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


def test_flash_attention_block_shape_invariance():
    q, k, v = _qkv(jax.random.PRNGKey(7), 1, 128, 2, 32, jnp.float32)
    outs = [
        flash_attention(q, k, v, block_q=bq, block_k=bk, interpret=True)
        for bq, bk in [(32, 32), (64, 32), (32, 64), (128, 128)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=1e-5, rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(s_blocks=st.integers(1, 4), h=st.sampled_from([1, 2, 4]),
       hd=st.sampled_from([16, 32]), seed=st.integers(0, 2 ** 16))
def test_flash_attention_property(s_blocks, h, hd, seed):
    s = 32 * s_blocks
    q, k, v = _qkv(jax.random.PRNGKey(seed), 1, s, h, hd, jnp.float32)
    out = flash_attention(q, k, v, block_q=32, block_k=32, interpret=True)
    expected = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("b,s,kh,g,hd", [
    (1, 128, 1, 1, 32), (2, 256, 2, 4, 64), (1, 512, 4, 2, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(b, s, kh, g, hd, dtype):
    h = kh * g
    keys = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(keys[0], (b, h, hd), dtype)
    kc = jax.random.normal(keys[1], (b, s, kh, hd), dtype)
    vc = jax.random.normal(keys[2], (b, s, kh, hd), dtype)
    length = jnp.asarray(np.random.default_rng(0).integers(1, s, b),
                         jnp.int32)
    out = decode_attention(q, kc, vc, length, block_k=64, interpret=True)
    expected = ref.decode_attention_ref(q, kc, vc, length)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expected, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


def test_decode_attention_respects_length():
    """Tokens beyond `length` must not influence the output."""
    b, s, kh, g, hd = 1, 128, 2, 2, 32
    keys = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(keys[0], (b, kh * g, hd))
    kc = jax.random.normal(keys[1], (b, s, kh, hd))
    vc = jax.random.normal(keys[2], (b, s, kh, hd))
    length = jnp.asarray([40], jnp.int32)
    out1 = decode_attention(q, kc, vc, length, block_k=32, interpret=True)
    kc2 = kc.at[:, 40:].set(99.0)
    vc2 = vc.at[:, 40:].set(-99.0)
    out2 = decode_attention(q, kc2, vc2, length, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               atol=1e-6)


@pytest.mark.parametrize("n,p,block", [(1024, 4, 256), (2048, 16, 512),
                                       (4096, 64, 1024)])
def test_partition_histogram(n, p, block):
    pids = jax.random.randint(jax.random.PRNGKey(1), (n,), 0, p, jnp.int32)
    hist = partition_histogram(pids, p, block=block, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(jnp.sum(hist, axis=0)),
        np.asarray(ref.partition_histogram_ref(pids, p)))


@pytest.mark.parametrize("n,p,d,block", [(512, 4, 4, 128), (2048, 16, 8, 512)])
def test_partition_scatter_matches_ref(n, p, d, block):
    pids = jax.random.randint(jax.random.PRNGKey(2), (n,), 0, p, jnp.int32)
    rows = jax.random.normal(jax.random.PRNGKey(3), (n, d))
    out, offsets = partition_scatter(rows, pids, p, block=block,
                                     interpret=True)
    r_out, r_off = ref.partition_scatter_ref(rows, pids, p)
    np.testing.assert_array_equal(np.asarray(offsets), np.asarray(r_off))
    np.testing.assert_allclose(np.asarray(out), np.asarray(r_out))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16), p=st.sampled_from([2, 8, 32]))
def test_partition_is_stable_grouping(seed, p):
    """Property: output is a permutation, grouped by pid, stable within."""
    n, d = 512, 2
    pids = jax.random.randint(jax.random.PRNGKey(seed), (n,), 0, p,
                              jnp.int32)
    rows = jnp.arange(n, dtype=jnp.float32)[:, None] * jnp.ones((1, d))
    out, offsets = partition_scatter(rows, pids, p, block=128,
                                     interpret=True)
    out_ids = np.asarray(out[:, 0]).astype(int)
    pids_np = np.asarray(pids)
    # permutation
    assert sorted(out_ids) == list(range(n))
    # grouped by pid, original order within group
    counts = np.bincount(pids_np, minlength=p)
    start = 0
    for part in range(p):
        seg = out_ids[start: start + counts[part]]
        expect = np.nonzero(pids_np == part)[0]
        np.testing.assert_array_equal(seg, expect)
        start += counts[part]


# -- dispatch-layer differentials: Pallas kernels vs ref vs numpy oracle ----------
#
# The dispatch layer (repro.kernels.ops) must agree with kernels/ref.py AND
# a from-scratch numpy oracle on the edges the raw kernels cannot express:
# empty input, a single bucket, every row in one bucket, and bucket counts
# that are not a power of two. force_kernel=True drives the Pallas path in
# interpret mode where shapes allow, so CI covers it without a TPU.


def _numpy_grouping_oracle(pids: np.ndarray, p: int):
    """Independent oracle: stable grouping permutation + exclusive offsets."""
    order = np.argsort(pids, kind="stable")
    counts = np.bincount(pids, minlength=p)
    offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)
    return order.astype(np.int32), offsets


@pytest.mark.parametrize("force_kernel", [False, True])
@pytest.mark.parametrize("case", [
    "empty", "single_bucket", "all_rows_one_bucket", "non_pow2_buckets"])
def test_grouping_indices_edges_match_numpy_oracle(case, force_kernel):
    from repro.kernels import ops as kops

    if case == "empty":
        pids, p = np.zeros((0,), np.int32), 4
    elif case == "single_bucket":
        pids, p = np.zeros((96,), np.int32), 1
    elif case == "all_rows_one_bucket":
        pids, p = np.full((128,), 2, np.int32), 8
    else:  # non_pow2_buckets
        rng = np.random.default_rng(5)
        pids, p = rng.integers(0, 7, size=200).astype(np.int32), 7
    order, offsets = kops.grouping_indices(jnp.asarray(pids), p,
                                           force_kernel=force_kernel)
    ref_order, ref_offsets = _numpy_grouping_oracle(pids, p)
    np.testing.assert_array_equal(np.asarray(offsets), ref_offsets)
    np.testing.assert_array_equal(np.asarray(order), ref_order)


@pytest.mark.parametrize("force_kernel", [False, True])
@pytest.mark.parametrize("n,p", [(0, 4), (256, 1), (128, 8), (384, 6)])
def test_dispatch_histogram_matches_ref_and_numpy(n, p, force_kernel):
    from repro.kernels import ops as kops

    rng = np.random.default_rng(n + p)
    pids = (rng.integers(0, p, size=n).astype(np.int32) if n else
            np.zeros((0,), np.int32))
    if n and p == 8:
        pids[:] = 3          # all rows in one bucket
    got = np.asarray(kops.partition_histogram(jnp.asarray(pids), p,
                                              force_kernel=force_kernel))
    np.testing.assert_array_equal(got, np.bincount(pids, minlength=p))
    np.testing.assert_array_equal(
        got, np.asarray(ref.partition_histogram_ref(jnp.asarray(pids), p)))


@pytest.mark.parametrize("force_kernel", [False, True])
@pytest.mark.parametrize("n,p,d", [(0, 4, 3), (128, 1, 2), (256, 8, 2),
                                   (320, 5, 4)])
def test_dispatch_scatter_matches_ref_and_numpy(n, p, d, force_kernel):
    from repro.kernels import ops as kops

    rng = np.random.default_rng(n + p + d)
    pids = (rng.integers(0, p, size=n).astype(np.int32) if n else
            np.zeros((0,), np.int32))
    rows = rng.standard_normal((n, d)).astype(np.float32)
    got, got_off = kops.partition_scatter(jnp.asarray(rows),
                                          jnp.asarray(pids), p,
                                          force_kernel=force_kernel)
    # numpy oracle: stable grouping
    order = np.argsort(pids, kind="stable")
    counts = np.bincount(pids, minlength=p)
    offsets = np.concatenate([[0], np.cumsum(counts)[:-1]]).astype(np.int32)
    np.testing.assert_array_equal(np.asarray(got_off), offsets)
    np.testing.assert_allclose(np.asarray(got), rows[order])
    if n:
        r_out, r_off = ref.partition_scatter_ref(jnp.asarray(rows),
                                                 jnp.asarray(pids), p)
        np.testing.assert_array_equal(np.asarray(got_off), np.asarray(r_off))
        np.testing.assert_allclose(np.asarray(got), np.asarray(r_out))
