"""Launcher-level coverage: every (arch x shape) cell plans cleanly on both
production mesh shapes (no device construction needed), and the CLI train
driver runs end-to-end on CPU."""

import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.common import applicable_shapes
from repro.core.config import SHAPES
from repro.parallel.strategies import make_rules, plan_cell


class FakeMesh:
    def __init__(self, shape: dict):
        self.shape = shape
        self.devices = np.empty(tuple(shape.values()), dtype=object)


MESHES = {
    "single": FakeMesh({"data": 16, "model": 16}),
    "multi": FakeMesh({"pod": 2, "data": 16, "model": 16}),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh_name", ["single", "multi"])
@pytest.mark.parametrize("profile", ["optimized", "baseline"])
def test_plan_all_cells(arch, mesh_name, profile):
    cfg = get_config(arch)
    mesh = MESHES[mesh_name]
    for shape_name in applicable_shapes(cfg):
        shape = SHAPES[shape_name]
        pc = plan_cell(cfg, shape, mesh, profile=profile)
        assert pc.attn_strategy != "auto"
        assert pc.moe_strategy != "auto"
        assert pc.layout in ("tp", "pure_dp")
        assert pc.microbatches >= 1
        if profile == "baseline":
            assert pc.layout == "tp"
            assert pc.moe_strategy != "shard_map_a2a"
            assert not pc.causal_skip
        rules = make_rules(mesh, cfg, shape, pc)
        # every logical axis must resolve to a valid spec
        spec = rules.spec("batch", "seq", "embed")
        assert spec is not None
        # divisibility of sharded batch
        n_b = rules.axis_size("batch")
        local = shape.global_batch // max(1, pc.microbatches) \
            if shape.mode == "train" else shape.global_batch
        if n_b > 1:
            assert local % n_b == 0, (arch, shape_name, local, n_b)


def test_big_models_not_pure_dp():
    mesh = MESHES["single"]
    for arch in ("qwen2-72b", "jamba-v0.1-52b"):
        pc = plan_cell(get_config(arch), SHAPES["train_4k"], mesh)
        assert pc.layout == "tp", arch


def test_small_models_pure_dp():
    mesh = MESHES["single"]
    for arch in ("xlstm-1.3b", "granite-moe-1b-a400m", "llama3.2-3b"):
        pc = plan_cell(get_config(arch), SHAPES["train_4k"], mesh)
        assert pc.layout == "pure_dp", arch


@pytest.mark.slow
def test_train_cli_end_to_end(tmp_path):
    from repro.launch.train import main

    losses = main(["--arch", "llama3.2-3b", "--steps", "12", "--batch", "2",
                   "--seq", "32", "--ckpt", str(tmp_path),
                   "--log-every", "4", "--ckpt-every", "6"])
    assert len(losses) >= 2


@pytest.mark.slow
def test_serve_cli_end_to_end():
    from repro.launch.serve import main

    done = main(["--arch", "llama3.2-3b", "--requests", "3",
                 "--max-new", "2", "--max-batch", "2", "--max-seq", "48"])
    assert len(done) == 3
