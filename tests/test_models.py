"""Per-arch smoke tests + model-math consistency checks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.common import applicable_shapes, concrete_inputs
from repro.core.config import SHAPES, ShapeConfig
from repro.models import (
    decode_step,
    forward,
    init_decode_state,
    init_lm,
)
from repro.models.attention import attention, init_attention
from repro.models.layers import tree_size
from repro.models.lm import prefill_step

SMOKE_TRAIN = ShapeConfig("smoke_train", 32, 2, "train")


@pytest.fixture(scope="module")
def smoke_models():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch, smoke=True)
            params, axes = init_lm(cfg, jax.random.PRNGKey(0))
            cache[arch] = (cfg, params, axes)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch, smoke_models):
    cfg, params, _ = smoke_models(arch)
    inputs = concrete_inputs(cfg, SMOKE_TRAIN)
    logits, aux = forward(params, inputs, cfg, remat="none", q_chunk=16,
                          ssm_chunk=8)
    b, s = SMOKE_TRAIN.global_batch, SMOKE_TRAIN.seq_len
    assert logits.shape[0] == b and logits.shape[1] == s
    assert logits.shape[2] >= cfg.vocab_size
    assert bool(jnp.isfinite(logits[..., : cfg.vocab_size]).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_shapes(arch, smoke_models):
    cfg, params, _ = smoke_models(arch)
    state = init_decode_state(cfg, 2, 64)
    tokens = jnp.zeros((2, 1), jnp.int32)
    logits, new_state = decode_step(params, state, tokens, cfg)
    assert logits.shape[:2] == (2, 1)
    assert bool(jnp.isfinite(logits[..., : cfg.vocab_size]).all())
    assert int(new_state["pos"][0]) == 1


@pytest.mark.parametrize("arch", ["llama3.2-3b", "jamba-v0.1-52b",
                                  "xlstm-1.3b", "granite-moe-1b-a400m"])
def test_prefill_matches_forward(arch, smoke_models):
    """prefill(prompt) last-position logits == forward(prompt) last logits"""
    cfg, params, _ = smoke_models(arch)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab_size, jnp.int32)
    state = init_decode_state(cfg, 2, 32)
    lg_p, state = prefill_step(params, state, {"tokens": tokens}, cfg,
                               q_chunk=16, ssm_chunk=8)
    lg_f, _ = forward(params, {"tokens": tokens}, cfg, remat="none",
                      q_chunk=16, ssm_chunk=8)
    np.testing.assert_allclose(np.asarray(lg_p[:, 0]),
                               np.asarray(lg_f[:, -1]), atol=5e-2)


@pytest.mark.parametrize("arch", ["llama3.2-3b", "jamba-v0.1-52b",
                                  "xlstm-1.3b"])
def test_decode_continues_prefill(arch, smoke_models):
    """prefill + decode_step == forward over the extended sequence.

    MoE archs need drop-free capacity here: a capacity-dropped token in the
    teacher-forced forward has no analogue in incremental decode (inherent
    to capacity-based routing, not a bug).
    """
    import dataclasses

    cfg, params, _ = smoke_models(arch)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (1, 12), 0,
                                cfg.vocab_size, jnp.int32)
    state = init_decode_state(cfg, 1, 32)
    lg, state = prefill_step(params, state, {"tokens": tokens}, cfg,
                             q_chunk=16, ssm_chunk=4)
    nxt = jnp.argmax(lg[:, 0, : cfg.vocab_size], -1)[:, None]
    nxt = nxt.astype(jnp.int32)
    lg_d, state = decode_step(params, state, nxt, cfg)
    extended = jnp.concatenate([tokens, nxt], axis=1)
    lg_f, _ = forward(params, {"tokens": extended}, cfg, remat="none",
                      q_chunk=13, ssm_chunk=13)
    np.testing.assert_allclose(np.asarray(lg_d[:, 0]),
                               np.asarray(lg_f[:, -1]), atol=5e-2)


def test_chunked_attention_matches_unchunked():
    cfg = get_config("mistral-nemo-12b", smoke=True)
    params, _ = init_attention(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model),
                          jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(64)[None], (2, 64))
    full = attention(params, x, pos, cfg, q_chunk=64)
    chunked = attention(params, x, pos, cfg, q_chunk=16)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               atol=2e-2, rtol=2e-2)


def test_causal_masking_no_future_leak():
    """Changing suffix tokens must not change prefix logits."""
    cfg = get_config("llama3.2-3b", smoke=True)
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 24), 0,
                              cfg.vocab_size, jnp.int32)
    lg1, _ = forward(params, {"tokens": toks}, cfg, remat="none", q_chunk=8)
    toks2 = toks.at[:, 16:].set(7)
    lg2, _ = forward(params, {"tokens": toks2}, cfg, remat="none", q_chunk=8)
    np.testing.assert_allclose(np.asarray(lg1[:, :16]),
                               np.asarray(lg2[:, :16]), atol=1e-3)


def test_recurrence_no_future_leak_ssm():
    """Causality for the scan-based families too."""
    for arch in ("xlstm-1.3b", "jamba-v0.1-52b"):
        cfg = get_config(arch, smoke=True)
        params, _ = init_lm(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(4), (1, 16), 0,
                                  cfg.vocab_size, jnp.int32)
        lg1, _ = forward(params, {"tokens": toks}, cfg, remat="none",
                         q_chunk=8, ssm_chunk=4)
        toks2 = toks.at[:, 12:].set(3)
        lg2, _ = forward(params, {"tokens": toks2}, cfg, remat="none",
                         q_chunk=8, ssm_chunk=4)
        np.testing.assert_allclose(np.asarray(lg1[:, :12]),
                                   np.asarray(lg2[:, :12]), atol=1e-3,
                                   err_msg=arch)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_matches_tree(arch, smoke_models):
    """Analytic param_count agrees with the actual tree (excluding the
    vocab-padding rows, which the analytic formula does not include)."""
    cfg, params, _ = smoke_models(arch)
    analytic = cfg.param_count()
    actual = tree_size(params)
    # allow vocab padding + stub frontend projections
    slack = (2 * 192 * cfg.d_model) + 2 * cfg.d_model * cfg.d_model
    assert analytic <= actual <= analytic + slack, (analytic, actual)


def test_applicable_shapes_policy():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        shapes = applicable_shapes(cfg)
        if arch in ("xlstm-1.3b", "jamba-v0.1-52b"):
            assert "long_500k" in shapes
        else:
            assert "long_500k" not in shapes


def test_moe_aux_loss_positive():
    cfg = get_config("granite-moe-1b-a400m", smoke=True)
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    inputs = concrete_inputs(cfg, SMOKE_TRAIN)
    _, aux = forward(params, inputs, cfg, remat="none", q_chunk=16)
    assert float(aux) >= 1.0   # >= 1 by Cauchy-Schwarz for any routing
