"""Observability: trace integrity, Chrome export round-trip, exact
critical paths on synthetic span DAGs, decision-audit diffing, metrics
compaction and the starved/error dashboard columns."""

import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.analytics import (
    QueryStrategy,
    Table,
    execute_query_runtime,
    reference_query_numpy,
    synth_table,
)
from repro.analytics.planner import build_query_workflow
from repro.analytics.table import distribute
from repro.core.controllers import GlobalController
from repro.obs import (
    Span,
    Tracer,
    critical_path,
    get_audit_log,
    get_tracer,
    set_tracer,
    to_chrome_trace,
    validate_chrome_trace,
)
from repro.runtime import MetricsSink, QueryJob, QueryScheduler, Runtime
from repro.runtime.metrics import InvocationRecord


def make_dist_tables(rows=4096, keyspace=2048, dim_rows=512,
                     fact_nodes=4, dim_nodes=2, seed=1):
    fact = synth_table("f", rows, keyspace, seed=seed)
    dimc = synth_table("d", dim_rows, keyspace, seed=seed + 1,
                       unique_keys=True)
    dim = Table({**dimc.columns,
                 "cat": jnp.arange(dim_rows, dtype=jnp.int32) % 64})
    ref = reference_query_numpy(fact, dim)
    return (distribute(fact, range(fact_nodes), "A"),
            distribute(dim, range(dim_nodes), "B"), ref)


@pytest.fixture(autouse=True)
def fresh_obs():
    get_tracer().clear()
    get_audit_log().clear()
    yield
    get_tracer().clear()
    get_audit_log().clear()


# -- tracer mechanics ------------------------------------------------------------


def test_span_nesting_and_intra_thread_parenting():
    tr = Tracer()
    with tr.span("outer", "executor", trace="t") as outer:
        with tr.span("inner", "store") as inner:
            assert inner.parent_id == outer.span_id
            assert inner.trace == "t"          # inherited from parent
    spans = tr.spans("t")
    assert [s.name for s in spans] == ["inner", "outer"]
    # nesting is temporal containment
    by = {s.name: s for s in spans}
    assert by["outer"].start <= by["inner"].start
    assert by["inner"].end <= by["outer"].end


def test_anchors_give_cross_thread_parents():
    tr = Tracer()
    root = tr.start("query/x", "scheduler", trace="x", parent=None)
    tr.anchor(("query", "x"), root)
    child = tr.start("stage/s", "executor", trace="x",
                     parent=tr.anchored(("query", "x")))
    assert child.parent_id == root.span_id
    tr.release_anchor(("query", "x"))
    assert tr.anchored(("query", "x")) is None
    tr.end(child)
    tr.end(root)


def test_ring_buffer_bounds_and_disabled_tracer():
    tr = Tracer(capacity=4)
    for i in range(7):
        with tr.span(f"s{i}", "store", trace="t"):
            pass
    assert len(tr.spans()) == 4
    assert [s.name for s in tr.spans()] == ["s3", "s4", "s5", "s6"]

    off = Tracer(enabled=False)
    with off.span("x", "store", trace="t") as sp:
        assert sp is None
    off.count("store_bytes/t", 5)
    assert off.spans() == [] and off.counters() == []
    assert off.start("x", "store") is None
    assert off.record("x", "store", 0.0) is None


def test_all_parents_live_in_buffer_after_real_query():
    fd, dd, _ = make_dist_tables()
    # static_merge shuffles both sides, so the kernel dispatch layer
    # (grouping_indices) fires inside the shuffle_write function bodies
    execute_query_runtime(fd, dd, QueryStrategy("static_merge"))
    spans = get_tracer().spans("query")
    assert spans, "a real query must leave spans"
    ids = {s.span_id for s in spans}
    dangling = [s for s in spans if s.parent_id is not None
                and s.parent_id not in ids]
    assert not dangling, [s.name for s in dangling]
    cats = {s.cat for s in spans}
    assert {"executor", "invoker", "store", "kernel"} <= cats
    # one non-store root: the executor's own query span (seed-time store
    # puts happen before any query root exists and stay roots)
    roots = [s for s in spans if s.parent_id is None and s.cat != "store"]
    assert [s.name for s in roots] == ["query/query"]


def test_chrome_trace_round_trip_with_scheduler():
    fd, dd, ref = make_dist_tables(rows=2048, dim_rows=256,
                                   fact_nodes=2, dim_nodes=1)
    gc = GlobalController({0: 4, 1: 4})
    rt = Runtime(gc, invoker="threads")
    sched = QueryScheduler(rt, policy="fair_share")
    sched.submit(QueryJob("obs_q", fd, dd, "static_hash", priority=3))
    res = sched.run()["obs_q"]
    assert res.ok, res.error
    np.testing.assert_allclose(res.sums, ref, atol=1e-3)

    trace = to_chrome_trace(get_tracer(), app="obs_q")
    info = validate_chrome_trace(json.dumps(trace))   # JSON round trip
    assert info["events"] > 0
    assert {"scheduler", "executor", "invoker", "store"} <= set(info["cats"])
    assert "store_bytes/obs_q" in info["counter_tracks"]
    assert any(t.startswith("slots/node") for t in info["counter_tracks"])
    # node processes + the control-plane process
    assert 1 in info["pids"] and any(p >= 10 for p in info["pids"])


def test_validate_chrome_trace_rejects_malformed():
    with pytest.raises(ValueError):
        validate_chrome_trace({"no": "traceEvents"})
    with pytest.raises(ValueError):
        validate_chrome_trace(
            {"traceEvents": [{"ph": "X", "pid": 1, "ts": -1, "dur": 1,
                              "name": "x", "tid": 0}]})


# -- critical path on synthetic span DAGs ----------------------------------------


def _stage(sid, name, deps, t0, t1):
    return Span(sid, "app", f"stage/{name}", "executor", t0, end=t1,
                attrs={"stage": name, "deps": list(deps)})


def _inv(sid, stage, t0, t1, node=0):
    return Span(sid, "app", f"app/{stage}/0", "invoker", t0, end=t1,
                node=node, attrs={"kind": "invocation", "stage": stage})


def test_critical_path_exact_on_synthetic_dag():
    # A (0-10) -> B (12-20); a non-bounding sibling A2 finishes earlier
    spans = [
        _stage(1, "A", (), 0.0, 10.0),
        _stage(2, "B", ("A",), 10.0, 20.0),
        _inv(3, "A", 0.0, 10.0),
        Span(4, "app", "app/A/1", "invoker", 0.0, end=4.0, node=1,
             attrs={"kind": "invocation", "stage": "A"}),
        _inv(5, "B", 12.0, 20.0, node=1),
        # store read inside the bounding B invocation: 3s transfer
        Span(6, "app", "get/A", "store", 13.0, end=16.0, parent_id=5),
    ]
    cp = critical_path(spans, app="app")
    assert [s.stage for s in cp.steps] == ["A", "B"]
    assert cp.steps[0].name == "app/A/0"          # max-end pred, not A/1
    assert cp.makespan == pytest.approx(20.0)
    assert cp.steps[1].queue == pytest.approx(2.0)   # 12 - 10 gap
    assert cp.steps[1].store == pytest.approx(3.0)
    assert cp.steps[1].compute == pytest.approx(5.0)
    assert cp.breakdown["compute"] == pytest.approx(15.0)
    assert cp.dominant == "compute"


def test_critical_path_slot_wait_bound():
    spans = [
        _stage(1, "A", (), 0.0, 30.0),
        _inv(2, "A", 0.0, 30.0),
        Span(3, "app", "slot_wait", "wait", 1.0, end=25.0, parent_id=2),
    ]
    cp = critical_path(spans, app="app")
    assert cp.dominant == "slot_wait"
    assert cp.breakdown["slot_wait"] == pytest.approx(24.0)
    assert cp.breakdown["compute"] == pytest.approx(6.0)


def test_critical_path_store_bound_and_batch_wait_inheritance():
    spans = [
        _stage(1, "A", (), 0.0, 20.0),
        # batch span owns the claim wait; its member owns the store time
        Span(2, "app", "batch/A@0", "invoker", 0.0, end=20.0, node=0,
             attrs={"kind": "batch", "stage": "A"}),
        Span(3, "app", "slot_wait", "wait", 0.0, end=2.0, parent_id=2),
        Span(4, "app", "app/A/0", "invoker", 2.0, end=20.0, node=0,
             parent_id=2, attrs={"kind": "invocation", "stage": "A"}),
        Span(5, "app", "put/out", "store", 5.0, end=17.0, parent_id=4),
    ]
    cp = critical_path(spans, app="app")
    assert cp.dominant == "store"
    assert cp.breakdown["store"] == pytest.approx(12.0)
    assert cp.breakdown["slot_wait"] == pytest.approx(2.0)  # inherited
    assert cp.breakdown["compute"] == pytest.approx(4.0)


def test_critical_path_overlapping_producer_consumer():
    """Pipelined launch: the consumer starts before either producer ends.
    The path follows the earliest-released producer with a zero queue gap,
    and the frontier-walk breakdown attributes each instant once, so the
    phase totals still sum to the makespan despite the overlap."""
    spans = [
        _stage(1, "A", (), 0.0, 12.0),
        _stage(2, "B", ("A",), 4.0, 14.0),
        _inv(3, "A", 0.0, 10.0),                       # released first
        Span(4, "app", "app/A/1", "invoker", 0.0, end=12.0, node=1,
             attrs={"kind": "invocation", "stage": "A"}),
        _inv(5, "B", 4.0, 14.0, node=1),               # overlaps both A's
        Span(6, "app", "get/A", "store", 5.0, end=10.0, parent_id=5),
    ]
    cp = critical_path(spans, app="app")
    assert [s.stage for s in cp.steps] == ["A", "B"]
    assert cp.steps[0].name == "app/A/0"      # earliest end, not latest
    assert cp.steps[1].queue == pytest.approx(0.0)   # overlap -> no idle
    assert cp.makespan == pytest.approx(14.0)
    # B extends the frontier only over 10..14 (w=4 of its 10s span), its
    # 5s store and 5s compute scale by 0.4 into that window
    assert cp.breakdown["store"] == pytest.approx(2.0)
    assert cp.breakdown["compute"] == pytest.approx(12.0)
    assert sum(cp.breakdown.values()) == pytest.approx(cp.makespan)


def test_critical_path_none_without_invocations():
    assert critical_path([], app="x") is None
    assert critical_path([_stage(1, "A", (), 0.0, 1.0)], app="app") is None


# -- decision audit --------------------------------------------------------------


def test_audit_entries_match_workflow_sequence():
    fd, dd, _ = make_dist_tables(rows=2048, dim_rows=256, seed=3)
    wf = build_query_workflow(QueryStrategy("dynamic"))
    execute_query_runtime(fd, dd, QueryStrategy("dynamic"), workflow=wf)
    run = wf.last_run
    want = [(stage, d.func) for stage, d in run.sequence]
    got = get_audit_log().sequence("query", nodes=[s for s, _ in want])
    assert got == want
    # the snapshot carries candidates + the upstream bindings
    entries = get_audit_log().entries("query")
    assert all(e.candidates for e in entries
               if e.node in {s for s, _ in want})
    join = next(e for e in entries if e.node == "join")
    assert ("scan", "scan_filter") in join.prior
    assert "A_scanned" in join.data_dist     # observed post-scan dist
    assert join.format()                     # human-readable, non-empty


def test_audit_log_bounded_and_clearable():
    log = get_audit_log()
    fd, dd, _ = make_dist_tables(rows=2048, dim_rows=256, seed=4)
    execute_query_runtime(fd, dd, QueryStrategy("static_hash"))
    assert log.entries("query")
    log.clear()
    assert log.entries() == []


# -- metrics satellites ----------------------------------------------------------


def _rec(stage, status, t0=0.0, t1=1.0, name=None):
    return InvocationRecord(name or f"a/{stage}/0", "a", stage, "f", 0, 0,
                            status, t0, t1)


def test_stage_metrics_counts_starved_and_error():
    sink = MetricsSink()
    sink.record(_rec("s", "ok"))
    sink.record(_rec("s", "starved", name="a/s/1"))
    sink.record(_rec("s", "error", name="a/s/2"))
    m = sink.by_stage("a")["s"]
    assert (m.ok, m.starved, m.error) == (1, 1, 1)
    fb = sink.profile_feedback("a")
    assert fb["s.starved"] == 1 and fb["s.error"] == 1


def test_format_table_sorted_by_first_start_with_totals():
    sink = MetricsSink()
    sink.record(_rec("late", "ok", t0=10.0, t1=11.0))
    sink.record(_rec("early", "ok", t0=0.0, t1=2.0))
    sink.record(_rec("early", "starved", t0=1.0, t1=1.0, name="a/early/1"))
    table = sink.format_table("a")
    lines = table.splitlines()
    order = [ln.split()[0] for ln in lines[1:]]
    assert order == ["early", "late", "TOTAL"]
    total = lines[-1].split()
    assert total[1] == "3"                   # invocations
    assert total[3] == "1"                   # starved column
    assert "stv" in lines[0] and "err" in lines[0]


def test_metrics_clear_per_app_and_scheduler_compaction():
    sink = MetricsSink()
    sink.record(_rec("s", "ok"))
    sink.record(InvocationRecord("b/s/0", "b", "s", "f", 0, 0, "ok", 0, 1))
    assert sink.clear(app="a") == 1
    assert [r.app for r in sink.records] == ["b"]
    assert sink.clear() == 1 and sink.records == []

    fd, dd, ref = make_dist_tables(rows=2048, dim_rows=256, seed=6,
                                   fact_nodes=2, dim_nodes=1)
    gc = GlobalController({0: 4, 1: 4})
    rt = Runtime(gc, invoker="threads")
    sched = QueryScheduler(rt, policy="fair_share", compact_metrics=True)
    sched.submit(QueryJob("cq", fd, dd, "static_hash"))
    res = sched.run()["cq"]
    assert res.ok, res.error
    np.testing.assert_allclose(res.sums, ref, atol=1e-3)
    # raw records compacted away, per-stage snapshot preserved
    assert rt.metrics.for_app("cq") == []
    assert res.stages and res.stages["final_agg"].ok == 1


def test_no_orphan_store_spans_in_pipelined_run():
    """Trace integrity across helper threads: a pipelined run issues store
    reads from ``PrefetchHandle`` background threads, whose spans must
    parent (via ``Tracer.adopt``) into the spawning invocation — never
    surface as orphan store-layer roots."""
    get_tracer().clear()
    fd, dd, ref = make_dist_tables(seed=11)
    got, _ = execute_query_runtime(fd, dd, QueryStrategy("static_merge"),
                                   invoker="threads", pipeline=True)
    np.testing.assert_allclose(got, ref, atol=1e-3)
    spans = get_tracer().spans("query")
    assert spans
    ids = {s.span_id for s in spans}
    dangling = [s for s in spans if s.parent_id is not None
                and s.parent_id not in ids]
    assert not dangling, [s.name for s in dangling]
    root = next(s for s in spans if s.name == "query/query")
    # seed-time puts predate the query root and the caller's result fetch
    # postdates it — both legitimately stay roots; every store span issued
    # while the query ran must have a parent
    orphans = [s for s in spans if s.cat == "store"
               and s.parent_id is None
               and root.start <= s.start <= root.end]
    assert not orphans, [s.name for s in orphans]


# -- overhead / disabled end-to-end ----------------------------------------------


def test_query_runs_clean_with_tracer_disabled():
    prev = set_tracer(Tracer(enabled=False))
    try:
        fd, dd, ref = make_dist_tables(rows=2048, dim_rows=256, seed=8)
        got, _ = execute_query_runtime(fd, dd, QueryStrategy("static_merge"))
        np.testing.assert_allclose(got, ref, atol=1e-3)
        assert get_tracer().spans() == []
    finally:
        set_tracer(prev)
