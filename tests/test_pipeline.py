"""Pipelined shuffle→join data plane: control-plane invisibility + kernels.

The tentpole contract under test: pipelining is a *pipeline decision node*
in the workflow, and whether the executor honors it (``pipeline=True``) is
pure mechanism — the decision audit sequence, the per-stage record counts,
lineage recovery sets and the numpy oracle result are identical with
pipelining on or off, including under seeded fault plans whose crashes and
losses land mid-prefetch. The fused partition+probe kernel is differential-
tested against a from-scratch numpy oracle, and the padding-waste counters
it feeds are checked end to end into ``profile_feedback``.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.analytics import (
    QueryStrategy,
    execute_query_runtime,
    synth_query_tables,
)
from repro.analytics.planner import build_query_workflow, tail_stages
from repro.core.controllers import GlobalController
from repro.core.decisions import DataDist, Decision, Schedule
from repro.kernels import ops as kops
from repro.runtime import (
    CrashFault,
    FaultInjector,
    FaultPlan,
    Runtime,
    StageLossFault,
)

STRATEGIES = ("static_merge", "static_hash", "dynamic", "dynamic_fig6")


@pytest.fixture(scope="module")
def tables():
    return synth_query_tables(4096, 512, seed=11)


def _run(tables, strat, pipeline, invoker="inline", plan=None,
         recovery="lineage"):
    fd, dd, ref = tables
    gc = GlobalController({n: 8 for n in range(4)})
    rt = Runtime(gc, invoker=invoker)
    if plan is not None:
        FaultInjector(plan).install(rt)
    wf = build_query_workflow(QueryStrategy(strat))
    got, _ = execute_query_runtime(fd, dd, QueryStrategy(strat), runtime=rt,
                                   workflow=wf, pipeline=pipeline,
                                   recovery=recovery)
    np.testing.assert_allclose(got, ref, atol=1e-3)
    assert sum(gc.used.values()) == 0
    return rt, wf.last_run


def _control_plane_view(rt, run):
    """Everything the control plane can observe about one run, normalized
    to be order-insensitive (a pipelined executor overlaps stages, so
    wall-clock ordering legitimately differs)."""
    return {
        "decisions": [(n, d.func, d.scale) for n, d in run.sequence],
        "records": sorted((r.name, r.status, r.attempt)
                          for r in rt.metrics.records),
        "bytes": {s: (m.bytes_in, m.bytes_out)
                  for s, m in rt.metrics.by_stage("query").items()},
        "recoveries": sorted((ev.lost_stage, ev.recovered)
                             for ev in rt.recoveries),
    }


# -- invisibility: the pipeline flag changes mechanism, never the plan -------------


@pytest.mark.parametrize("strat", STRATEGIES)
def test_pipeline_invisible_to_control_plane(tables, strat):
    views = []
    for pipeline in (False, True):
        rt, run = _run(tables, strat, pipeline)
        views.append(_control_plane_view(rt, run))
    assert views[0] == views[1]
    # and the decision node really bound a pipelining mode (small tables ->
    # the fused kernel path), it just wasn't honored in the barrier run
    bound = dict((n, f) for n, f, _ in views[0]["decisions"])
    assert bound["pipeline"] in ("fused", "pipelined", "barrier")


@pytest.mark.parametrize("strat", ("static_merge", "dynamic"))
def test_pipeline_invisible_under_threads_invoker(tables, strat):
    views = []
    for pipeline in (False, True):
        rt, run = _run(tables, strat, pipeline, invoker="threads")
        views.append(_control_plane_view(rt, run))
    assert views[0] == views[1]


def test_pipeline_decision_modes_from_context():
    """The decision node is data-driven: tiny buckets -> fused, big buckets
    with free slots -> pipelined, big buckets on a saturated cluster ->
    barrier."""
    from repro.analytics.planner import FUSED_BUCKET_BYTES, pipeline_decision
    from repro.core.decisions import DecisionContext, NodeStatus

    def ctx(bucket_bytes, free):
        join = Decision("merge_join", 4, Schedule("round-robin", (0, 1)))
        total = bucket_bytes * 4
        return DecisionContext(
            data_dist={"A": DataDist("A", {0: total // 2}),
                       "B": DataDist("B", {1: total // 2})},
            node_status=NodeStatus(total_slots={0: 8, 1: 8},
                                   free_slots={0: free, 1: 0}),
            decisions={"join": join})

    assert pipeline_decision(ctx(1 << 10, free=4)).func == "fused"
    big = FUSED_BUCKET_BYTES * 8
    assert pipeline_decision(ctx(big, free=4)).func == "pipelined"
    assert pipeline_decision(ctx(big, free=0)).func == "barrier"


def test_needs_edges_cover_actual_producers():
    """Partition-granularity readiness is sound only if ``needs`` names
    every producer whose output the invocation may read: hash-distributed
    joins need ALL shuffle writers (all-to-all), aggregation is 1:1."""
    join_d = Decision("merge_join", 4, Schedule("round-robin", (0, 1)))
    stages = {s.name: s for s in tail_stages(
        "q", [(0, 0), (1, 1)], [(0, 0)], join_d,
        DataDist("A", {0: 1 << 20}),
        exchange=Decision("shuffle", 4, Schedule("round-robin", (0, 1))),
        pipeline=Decision("pipelined", 2, Schedule("round-robin", (0, 1))))}
    writers = {f"q/shuffle_fact/{i}" for i in (0, 1)} | {"q/shuffle_dim/0"}
    for iv in stages["join"].invocations:
        assert set(iv.needs) == writers
        assert iv.params["plan"] == "pipelined"
    for iv in stages["shuffle_fact"].invocations:
        assert iv.needs == (f"q/scan_fact/{iv.index}",)
    for iv in stages["partial_agg"].invocations:
        assert iv.needs == (f"q/join/{iv.index}",)


# -- invariance under fault plans --------------------------------------------------


def test_pipeline_invariant_under_crash_landing_mid_join(tables):
    """A crash-after on a join invocation lands after its prefetches were
    issued and joined; the retry re-prefetches under a fresh context. The
    recovery behavior (statuses, attempts, result) matches the barrier
    run's exactly."""
    views = []
    for pipeline in (False, True):
        plan = FaultPlan(crashes=[CrashFault("join", index=0, when="after")])
        rt, run = _run(tables, "static_merge", pipeline, plan=plan)
        views.append(_control_plane_view(rt, run))
    assert views[0] == views[1]
    statuses = [s for (n, s, _) in views[1]["records"]
                if n == "query/join/0"]
    assert statuses == ["crashed", "ok"]


def test_pipeline_invariant_under_bucket_loss_mid_prefetch(tables):
    """Losing a shuffle bucket stage on its first read makes the prefetch
    worker itself hit the lost tombstone; the ``StageLostError`` must
    surface at the consumer's ``get`` and drive the *same* lineage
    recovery set as the barrier run."""
    views = []
    for pipeline in (False, True):
        plan = FaultPlan(losses=[StageLossFault("fact_buckets", on_read=1)])
        rt, run = _run(tables, "static_merge", pipeline, plan=plan)
        views.append(_control_plane_view(rt, run))
    assert views[0] == views[1]
    assert views[1]["recoveries"], "the loss plan never fired"


@pytest.mark.parametrize("seed", (3, 17))
def test_pipeline_invariant_under_seeded_chaos(tables, seed):
    views = []
    for pipeline in (False, True):
        plan = FaultPlan.seeded(seed, stages=("scan_fact", "join"),
                                data_stages=("joined",), delay=0.01)
        rt, run = _run(tables, "dynamic", pipeline, plan=plan)
        views.append(_control_plane_view(rt, run))
    assert views[0] == views[1]


# -- fused partition+probe kernel vs numpy oracle ----------------------------------


def _fused_oracle(pk, v0, v1, bk, bc, g):
    lut = {int(k): int(c) for k, c in zip(bk, bc)}
    grp = np.zeros(len(pk), np.int32)
    wgt = np.zeros(len(pk), np.float32)
    for i, k in enumerate(pk):
        if int(k) in lut:
            grp[i] = lut[int(k)] % g
            wgt[i] = np.float32(v0[i]) * np.float32(v1[i])
    return grp, wgt


def _fused_case(n, m, seed=0, match=True):
    rng = np.random.default_rng(seed)
    bk = rng.permutation(2 * max(m, 1))[:m].astype(np.int32)
    bc = rng.integers(0, 1000, m).astype(np.int32)
    if match or m == 0:
        pk = rng.choice(np.concatenate([bk, bk + 2 * max(m, 1)])
                        if m else np.arange(1), size=n).astype(np.int32)
    else:
        pk = (rng.integers(0, 1 << 20, n) + 4 * max(m, 1)).astype(np.int32)
    v0 = rng.standard_normal(n).astype(np.float32)
    v1 = rng.standard_normal(n).astype(np.float32)
    return pk, v0, v1, bk, bc


@pytest.mark.parametrize("n,m,kwargs", [
    (0, 16, {}),                    # empty probe side
    (16, 0, {}),                    # empty build bucket
    (1, 1, {}),                     # single rows
    (100, 7, {}),                   # non-power-of-two both sides
    (257, 63, {}),                  # just past a shape-class boundary
    (512, 128, {"match": False}),   # no probe key matches
    (4096, 4096, {}),               # at the VMEM-rows gate
    (512, 5000, {}),                # past the gate -> jitted fallback
])
def test_fused_probe_groups_matches_oracle(n, m, kwargs):
    pk, v0, v1, bk, bc = _fused_case(n, m, seed=n + m, **kwargs)
    grp, wgt = kops.fused_probe_groups(pk, v0, v1, bk, bc, 64)
    egrp, ewgt = _fused_oracle(pk, v0, v1, bk, bc, 64)
    np.testing.assert_array_equal(np.asarray(grp), egrp)
    np.testing.assert_allclose(np.asarray(wgt), ewgt, atol=1e-5)


def test_fused_probe_groups_duplicate_probe_keys():
    pk = np.asarray([5, 5, 5, 9, 9, 2, 2, 2], np.int32)
    v0 = np.arange(8, dtype=np.float32)
    v1 = np.ones(8, np.float32)
    bk = np.asarray([5, 2], np.int32)
    bc = np.asarray([70, 130], np.int32)
    grp, wgt = kops.fused_probe_groups(pk, v0, v1, bk, bc, 64)
    egrp, ewgt = _fused_oracle(pk, v0, v1, bk, bc, 64)
    np.testing.assert_array_equal(np.asarray(grp), egrp)
    np.testing.assert_allclose(np.asarray(wgt), ewgt, atol=1e-6)


def test_fused_probe_kernel_interpret_matches_oracle():
    """``force_kernel`` exercises the Pallas one-hot probe body (interpret
    mode off-TPU) instead of the jitted sorted-search fallback."""
    pk, v0, v1, bk, bc = _fused_case(256, 64, seed=42)
    grp, wgt = kops.fused_probe_groups(pk, v0, v1, bk, bc, 64,
                                       force_kernel=True)
    egrp, ewgt = _fused_oracle(pk, v0, v1, bk, bc, 64)
    np.testing.assert_array_equal(np.asarray(grp), egrp)
    np.testing.assert_allclose(np.asarray(wgt), ewgt, atol=1e-5)


# -- padding-waste counters --------------------------------------------------------


def test_padding_counters_tally_shape_class_waste():
    kops.reset_padding_counters()
    pids = np.zeros(100, np.int32)
    kops.grouping_indices(pids, 4)
    actual, padded = kops.padding_counters()
    assert actual == 100 and padded >= 128   # next shape class up


def test_padding_overhead_surfaces_in_profile_feedback(tables):
    rt, _ = _run(tables, "static_merge", pipeline=False)
    fb = rt.metrics.profile_feedback("query")
    pads = {k: v for k, v in fb.items() if k.endswith(".padding_overhead")}
    assert pads, "no padding_overhead feedback emitted"
    assert any(v > 0 for v in pads.values())   # 4096-row parts split unevenly
    assert all(0.0 <= v < 1.0 for v in pads.values())
    assert "pad%" in rt.metrics.format_table("query")
