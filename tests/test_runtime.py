"""Serverless runtime: oracle equivalence under every strategy, shuffle-store
byte accounting, preemption/retry of stateless invocations, trace replay."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.analytics import (
    QueryStrategy,
    Table,
    execute_query_runtime,
    make_cluster,
    reference_query_numpy,
    synth_table,
)
from repro.analytics.table import distribute
from repro.core.controllers import GlobalController, PrivateController
from repro.runtime import (
    InlineInvoker,
    MetricsSink,
    Runtime,
    ShuffleStore,
    ThreadPoolInvoker,
)

STRATEGIES = ("static_merge", "static_hash", "dynamic", "dynamic_fig6")


def make_dist_tables(rows=4096, keyspace=2048, dim_rows=512,
                     fact_nodes=4, dim_nodes=2, seed=1):
    fact = synth_table("f", rows, keyspace, seed=seed)
    dimc = synth_table("d", dim_rows, keyspace, seed=seed + 1,
                       unique_keys=True)
    dim = Table({**dimc.columns,
                 "cat": jnp.arange(dim_rows, dtype=jnp.int32) % 64})
    ref = reference_query_numpy(fact, dim)
    return (distribute(fact, range(fact_nodes), "A"),
            distribute(dim, range(dim_nodes), "B"), ref)


# -- oracle equivalence across all four strategies -------------------------------


@pytest.mark.parametrize("strat", STRATEGIES)
def test_runtime_query_matches_oracle(strat):
    fd, dd, ref = make_dist_tables()
    got, runtime = execute_query_runtime(fd, dd, QueryStrategy(strat))
    np.testing.assert_allclose(got, ref, atol=1e-3)
    stages = runtime.metrics.by_stage("query")
    assert stages["final_agg"].ok == 1
    assert all(m.preempted == 0 for m in stages.values())


def test_runtime_query_threadpool_matches_oracle():
    fd, dd, ref = make_dist_tables(seed=5)
    got, runtime = execute_query_runtime(
        fd, dd, QueryStrategy("static_merge"), invoker="threads")
    np.testing.assert_allclose(got, ref, atol=1e-3)
    got2, _ = execute_query_runtime(
        fd, dd, QueryStrategy("static_hash"), invoker="threads")
    np.testing.assert_allclose(got2, ref, atol=1e-3)


def test_runtime_folds_metrics_into_decision_profile():
    """Paper Fig. 5 step 4: execution feedback lands in the app profile."""
    fd, dd, _ = make_dist_tables()
    gc = GlobalController({n: 8 for n in range(4)})
    pc = PrivateController("query", gc, priority=10)
    execute_query_runtime(fd, dd, QueryStrategy("static_hash"), gc=gc, pc=pc)
    assert pc.profile["join.invocations"] >= 1
    assert pc.profile["join.seconds"] > 0
    assert pc.profile["scan_fact.bytes_out"] > 0
    assert "A_scanned" in pc.data_dist     # post-filter distribution observed


# -- shuffle store accounting -----------------------------------------------------


def test_store_byte_accounting_and_cross_node_reads():
    store = ShuffleStore()
    t0 = synth_table("t", 256, 512, seed=0)
    t1 = synth_table("t", 128, 512, seed=1)
    n0, n1 = t0.nbytes, t1.nbytes
    store.put("app", "s", 0, t0, node=0, writer="w0")
    store.put("app", "s", 0, t1, node=1, writer="w1")

    got = store.get("app", "s", 0, node=0)      # w1's slice is remote
    assert got.num_rows == 384
    assert store.written_bytes == {0: n0, 1: n1}
    assert store.sent_bytes == {1: n1}
    assert store.cross_node_bytes == n1

    store.get("app", "s", 0, node=2)            # both slices remote
    assert store.cross_node_bytes == n1 + n0 + n1

    dist = store.data_dist("app", "s")
    assert dist.size == n0 + n1
    assert dict(dist.bytes_per_node) == {0: n0, 1: n1}
    assert dist.rows == 384


def test_store_retry_overwrites_and_delete_reclaims():
    store = ShuffleStore()
    big = synth_table("t", 512, 512, seed=0)
    small = synth_table("t", 64, 512, seed=0)
    store.put("app", "s", 0, big, node=0, writer="inv")
    store.put("app", "s", 0, small, node=0, writer="inv")   # retry: replace
    assert store.get("app", "s", 0, node=0).num_rows == 64
    assert store.resident_bytes[0] == small.nbytes
    freed = store.delete_stage("app", "s")
    assert freed == small.nbytes
    assert store.resident_bytes[0] == 0
    assert store.get("app", "s", 0, node=0) is None


def test_runtime_query_shuffle_volume_accounted():
    fd, dd, _ = make_dist_tables()
    _, runtime = execute_query_runtime(fd, dd, QueryStrategy("static_merge"))
    store = runtime.store
    # the all-to-all shuffle must move bytes off-node, and everything a node
    # served remotely is part of the global cross-node total
    assert store.cross_node_bytes > 0
    assert sum(store.sent_bytes.values()) == store.cross_node_bytes
    # scan output stayed resident (only buckets/joined/partials are GC'd)
    assert store.stage_bytes("query", "scan_fact") > 0
    assert store.stage_bytes("query", "fact_buckets") == 0   # ephemeral


# -- preemption of a low-priority invocation mid-DAG ------------------------------


def test_low_priority_invocation_preempted_mid_dag_and_retried():
    fd, dd, ref = make_dist_tables(fact_nodes=2, dim_nodes=2)
    gc = GlobalController({0: 1, 1: 1})      # one slot per node: contended
    store, metrics = ShuffleStore(), MetricsSink()
    fired = []

    def urgent_arrival(inv, attempt):
        # while the low-priority join holds its slot, a high-priority claim
        # lands on the same node -> Omega preempts the in-flight invocation
        if inv.stage == "join" and inv.index == 0 and not fired:
            fired.append(inv.name)
            hi = gc.commit("urgent", 99, [inv.node])
            gc.release(hi)

    invoker = InlineInvoker(gc, store, metrics, intercept=urgent_arrival)
    runtime = Runtime(gc, invoker=invoker, store=store, metrics=metrics)
    got, _ = execute_query_runtime(
        fd, dd, QueryStrategy("static_hash"), runtime=runtime, priority=0)

    np.testing.assert_allclose(got, ref, atol=1e-3)      # retry healed it
    records = [r for r in metrics.records if r.status == "preempted"]
    assert len(records) == 1 and records[0].stage == "join"
    assert any(p.victim.priority == 0 for p in gc.preemptions)
    retried = [r for r in metrics.records
               if r.name == records[0].name and r.status == "ok"]
    assert retried and retried[0].attempt == records[0].attempt + 1


def test_threadpool_invoker_contends_through_controller():
    """More in-flight instances than slots: claims serialize, all complete."""
    fd, dd, ref = make_dist_tables(fact_nodes=2, dim_nodes=2)
    gc = GlobalController({0: 1, 1: 1})
    runtime = Runtime(gc, invoker="threads")
    got, _ = execute_query_runtime(
        fd, dd, QueryStrategy("static_hash"), runtime=runtime)
    np.testing.assert_allclose(got, ref, atol=1e-3)
    assert sum(gc.used.values()) == 0        # every claim released


# -- trace replay into the simulator ----------------------------------------------


def test_invocation_trace_replays_into_simulator():
    fd, dd, _ = make_dist_tables()
    _, runtime = execute_query_runtime(fd, dd, QueryStrategy("static_merge"))
    ok = [r for r in runtime.metrics.records if r.status == "ok"]
    gc2, sim = make_cluster(4)
    n = runtime.replay_into(sim)
    assert n == len(ok)
    out = sim.run()
    assert len(sim.done) == n
    assert out["completion"]["query"] > 0
    # replay preserves the DAG: the final aggregate finishes last
    assert sim.tasks["query/final_agg/0"].finished == \
        max(t.finished for t in sim.tasks.values())
