"""Multi-query sharing engine: QueryScheduler policies, fair-share slot
rationing, starvation semantics, and concurrent simulator/runtime decision
parity over the shared substrate."""

import random
import threading
import time

import numpy as np
import pytest

from repro.analytics import (
    QueryStrategy,
    build_query_workflow,
    make_cluster,
    plan_query_tasks,
    synth_query_tables,
)
from repro.core.controllers import GlobalController, PrivateController
from repro.runtime import (
    FairShareGate,
    InlineInvoker,
    Invocation,
    InvocationError,
    MetricsSink,
    QueryJob,
    QueryScheduler,
    Runtime,
    ShuffleStore,
)

STRATEGIES = ("static_merge", "static_hash", "dynamic", "dynamic_fig6")


def make_query(seed, rows=2048, dim_rows=256, keyspace=1024, fact_nodes=4,
               dim_nodes=2):
    return synth_query_tables(rows, dim_rows, keyspace=keyspace, seed=seed,
                              fact_nodes=fact_nodes, dim_nodes=dim_nodes)


# -- starvation semantics regression (the busy-spin bug) --------------------------


def test_starved_invocation_succeeds_once_slot_frees():
    """With ``starve_wait=0`` the old loop burned every attempt instantly
    (`claim is None` -> continue); the event-based wait blocks on the
    controller's release event, so a starved invocation succeeds the moment
    the hog releases — within the same small ``max_attempts`` budget."""
    gc = GlobalController({0: 1})
    hog = gc.commit("hog", priority=5, placement=[0])
    store, metrics = ShuffleStore(), MetricsSink()
    invoker = InlineInvoker(gc, store, metrics, max_attempts=5,
                            starve_wait=0.0)
    invoker.registry = {"noop": lambda ctx: None}
    inv = Invocation("lo/s/0", "lo", "s", 0, "noop", node=0, priority=0)

    done = []
    t = threading.Thread(
        target=lambda: (invoker.run_stage([inv]), done.append(True)))
    t.start()
    time.sleep(0.15)                 # old code: already starved and raised
    assert not done, "invocation gave up while the slot was still held"
    gc.release(hog)
    t.join(timeout=10)
    assert not t.is_alive() and done
    recs = [r for r in metrics.records if r.name == "lo/s/0"]
    assert [r.status for r in recs] == ["ok"]
    assert sum(gc.used.values()) == 0


def test_truly_starved_invocation_still_errors_within_budget():
    gc = GlobalController({0: 1})
    gc.commit("hog", priority=5, placement=[0])   # never released
    invoker = InlineInvoker(gc, ShuffleStore(), MetricsSink(),
                            max_attempts=3, starve_wait=0.01)
    invoker.registry = {"noop": lambda ctx: None}
    inv = Invocation("lo/s/0", "lo", "s", 0, "noop", node=0, priority=0)
    with pytest.raises(InvocationError, match="no slot"):
        invoker.run_stage([inv])


# -- fair-share gate arithmetic ---------------------------------------------------


def _inv(app, priority=0):
    return Invocation(f"{app}/s/0", app, "s", 0, "noop", node=0,
                      priority=priority)


def test_fair_share_gate_entitlements_and_work_conservation():
    gate = FairShareGate(total_slots=4, timeout=2.0)
    gate.register("a", weight=3.0)
    gate.register("b", weight=1.0)
    assert gate.entitlement("a") == 3
    assert gate.entitlement("b") == 1

    for _ in range(3):
        gate.acquire(_inv("a"))
    # work conservation: b is idle, so a may exceed its entitlement
    gate.acquire(_inv("a"))
    assert gate.in_use["a"] == 4

    # b's demand now blocks until a releases; once a slot frees, the
    # under-served app wins it even though a is also waiting
    got_b = threading.Event()
    t_b = threading.Thread(
        target=lambda: (gate.acquire(_inv("b")), got_b.set()))
    t_b.start()
    time.sleep(0.05)
    assert not got_b.is_set()        # full: b waits
    a_acquired = threading.Event()
    t_a = threading.Thread(
        target=lambda: (gate.acquire(_inv("a")), a_acquired.set()))
    t_a.start()
    time.sleep(0.05)
    gate.release(_inv("a"))          # one slot frees; b is under-served
    t_b.join(timeout=5)
    assert got_b.is_set()
    assert gate.in_use["b"] == 1
    assert not a_acquired.is_set(), \
        "over-entitled app took the slot from the under-served waiter"
    gate.release(_inv("b"))          # b done -> a's waiter proceeds
    t_a.join(timeout=5)
    assert a_acquired.is_set()


def test_gate_token_released_when_claim_attempt_raises():
    """A commit-path exception (e.g. a listener raising mid-preemption)
    must not leak the fair-share gate token."""
    gc = GlobalController({0: 1})
    gate = FairShareGate(total_slots=1, timeout=1.0)
    gate.register("lo", weight=1.0)
    invoker = InlineInvoker(gc, ShuffleStore(), MetricsSink(),
                            max_attempts=2, gate=gate)
    invoker.registry = {"noop": lambda ctx: None}

    def bad_listener(event, claim):
        raise RuntimeError("listener exploded")

    gc.subscribe(bad_listener)
    inv = Invocation("lo/s/0", "lo", "s", 0, "noop", node=0, priority=0)
    with pytest.raises(RuntimeError, match="listener exploded"):
        invoker.run_stage([inv])
    assert gate.in_use["lo"] == 0            # token returned despite the raise
    # the controller rolled the booked claim back too: no slot leak
    assert gc.used == {0: 0}
    assert gc.claims == {}


def test_fair_share_gate_unregister_redistributes():
    gate = FairShareGate(total_slots=8, timeout=2.0)
    gate.register("a", weight=1.0)
    gate.register("b", weight=1.0)
    assert gate.entitlement("a") == 4
    gate.unregister("b")
    assert gate.entitlement("a") == 8


# -- scheduler policies -----------------------------------------------------------


def test_scheduler_fifo_serializes_in_arrival_order():
    gc = GlobalController({n: 8 for n in range(4)})
    sched = QueryScheduler(Runtime(gc), policy="fifo")
    queries = {f"q{i}": make_query(40 + 3 * i) for i in range(3)}
    for app, (fd, dd, _) in queries.items():
        sched.submit(QueryJob(app, fd, dd, "static_hash", priority=0))
    results = sched.run()
    for app, (_, _, ref) in queries.items():
        assert results[app].ok, results[app].error
        np.testing.assert_allclose(results[app].sums, ref, atol=1e-3)
    # strict serialization: each query starts after the previous finished
    ordered = [results[f"q{i}"] for i in range(3)]
    for prev, nxt in zip(ordered, ordered[1:]):
        assert nxt.started >= prev.finished
    assert sum(gc.used.values()) == 0


def test_scheduler_priority_admits_high_priority_first():
    gc = GlobalController({n: 8 for n in range(4)})
    sched = QueryScheduler(Runtime(gc), policy="priority")
    fd, dd, ref_lo = make_query(50)
    fd2, dd2, ref_hi = make_query(53)
    sched.submit(QueryJob("lo", fd, dd, "static_hash", priority=0))
    sched.submit(QueryJob("hi", fd2, dd2, "static_hash", priority=10))
    results = sched.run()
    assert results["hi"].started <= results["lo"].started
    assert results["hi"].finished <= results["lo"].started
    np.testing.assert_allclose(results["hi"].sums, ref_hi, atol=1e-3)
    np.testing.assert_allclose(results["lo"].sums, ref_lo, atol=1e-3)


def test_scheduler_fair_share_runs_concurrently_and_correctly():
    gc = GlobalController({n: 8 for n in range(4)})
    runtime = Runtime(gc, invoker="threads", max_workers=8)
    sched = QueryScheduler(runtime, policy="fair_share")
    queries = {}
    for i in range(4):
        app = f"q{i}"
        queries[app] = make_query(60 + 3 * i)
        fd, dd, _ = queries[app]
        sched.submit(QueryJob(app, fd, dd, STRATEGIES[i % 4],
                              priority=10 if i % 2 else 0))
    results = sched.run()
    for app, (_, _, ref) in queries.items():
        assert results[app].ok, results[app].error
        np.testing.assert_allclose(results[app].sums, ref, atol=1e-3)
    # really concurrent: some pair of queries' execution spans intersect
    spans = sorted((r.started, r.finished) for r in results.values())
    assert any(a_end > b_start for (_, a_end), (b_start, _)
               in zip(spans, spans[1:]))
    # the gate came off the invoker and no slots leaked
    assert runtime.invoker.gate is None
    assert sum(gc.used.values()) == 0
    # per-query decision sequences were captured
    assert all(len(r.decisions) == 8 for r in results.values())


def test_scheduler_fair_share_respects_store_quotas():
    gc = GlobalController({n: 8 for n in range(4)})
    runtime = Runtime(gc, invoker="threads", max_workers=8)
    sched = QueryScheduler(runtime, policy="fair_share")
    fd, dd, ref = make_query(70)
    input_bytes = fd.nbytes + dd.nbytes
    quota = 6 * input_bytes
    sched.submit(QueryJob("capped", fd, dd, "static_merge", priority=5,
                          quota=quota))
    results = sched.run()
    assert results["capped"].ok, results["capped"].error
    np.testing.assert_allclose(results["capped"].sums, ref, atol=1e-3)
    assert runtime.store.peak_bytes["capped"] <= quota
    # end-of-query cleanup: the quota is lifted and the sealed
    # consumed-ephemeral stages are gone (parity with the quota-less path)
    assert runtime.store.quota("capped") is None
    assert runtime.store.stage_bytes("capped", "fact_buckets") == 0
    assert runtime.store.stage_bytes("capped", "dim_buckets") == 0
    # non-ephemeral state (inputs, scans, result) stays inspectable
    assert runtime.store.stage_bytes("capped", "result") > 0


def test_scheduler_surfaces_per_query_errors():
    class BoomStrategy:
        """Join decision node that always fails (no fallback)."""

        name = "boom"

        def join_method(self, ctx):
            raise RuntimeError("boom: decision node exploded")

    gc = GlobalController({n: 8 for n in range(4)})
    sched = QueryScheduler(Runtime(gc), policy="fifo")
    fd, dd, ref = make_query(80)
    sched.submit(QueryJob("bad", fd, dd, BoomStrategy()))
    sched.submit(QueryJob("good", fd, dd, "static_hash"))
    results = sched.run()
    assert not results["bad"].ok
    assert isinstance(results["bad"].error, RuntimeError)
    assert results["good"].ok
    np.testing.assert_allclose(results["good"].sums, ref, atol=1e-3)
    assert sum(gc.used.values()) == 0


# -- differential: concurrent runtime vs simulator decision parity ----------------


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_concurrent_mix_sim_and_runtime_bind_identical_decisions(seed):
    """Randomized workload mixes: N queries run *concurrently* on the real
    runtime under fair-share; the simulator then plans each query through
    the same workflow objects. Every app must materialize the identical
    per-query decision sequence on both planes — concurrency (slot
    contention, gate waits, interleaved store traffic) must not leak into
    the decision workflows."""
    from repro.obs import get_audit_log

    audit = get_audit_log()
    audit.clear()
    rng = random.Random(seed)
    n_queries = rng.randint(2, 4)
    jobs = []
    for i in range(n_queries):
        app = f"mix{i}"
        strat = rng.choice(STRATEGIES)
        fd, dd, ref = make_query(seed=100 * seed + 7 * i,
                                 rows=rng.choice([1024, 2048, 4096]),
                                 dim_rows=rng.choice([128, 256]))
        wf = build_query_workflow(QueryStrategy(strat))
        jobs.append((app, strat, fd, dd, ref, wf,
                     rng.choice([0, 5, 10])))

    gc = GlobalController({n: 8 for n in range(4)})
    runtime = Runtime(gc, invoker="threads", max_workers=8)
    sched = QueryScheduler(runtime, policy="fair_share")
    for app, strat, fd, dd, _, wf, prio in jobs:
        sched.submit(QueryJob(app, fd, dd, strat, priority=prio,
                              workflow=wf))
    results = sched.run()

    runtime_seqs = {}
    for app, strat, fd, dd, ref, wf, _ in jobs:
        assert results[app].ok, results[app].error
        np.testing.assert_allclose(results[app].sums, ref, atol=1e-3)
        runtime_seqs[app] = results[app].decisions

    # simulator pass: same workflow objects, one shared simulated cluster
    gc_sim, sim = make_cluster(4)
    for app, strat, fd, dd, _, wf, prio in jobs:
        pc = PrivateController(app, gc_sim, priority=10)
        plan_query_tasks(sim, pc, fd, dd, QueryStrategy(strat), app=app,
                         workflow=wf)
        sim_seq = list(wf.last_run.sequence)
        assert sim_seq == runtime_seqs[app], \
            f"{app} [{strat}]: decision sequences diverged across planes"
        # audit parity: the per-app audit stream holds the concurrent
        # runtime bindings followed by the sim bindings — both must equal
        # the simulator decision sequence, despite interleaved execution
        funcs = [(s, d.func) for s, d in sim_seq]
        assert audit.sequence(app, nodes=[s for s, _ in sim_seq]) == \
            funcs + funcs, f"{app} [{strat}]: audit log diverged"
    out = sim.run()
    for app, *_ in jobs:
        assert out["completion"][app] > 0
