"""Serving engine + adaptive batching decision node."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.controllers import GlobalController
from repro.core.decisions import DecisionContext
from repro.models import init_lm
from repro.serving import Request, ServingEngine
from repro.serving.engine import batching_decision


@pytest.fixture(scope="module")
def engine_setup():
    cfg = get_config("llama3.2-3b", smoke=True)
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _ctx(queue, slo_ms=200.0, decode_ms=5.0, max_batch=8):
    gc = GlobalController({0: max_batch})
    ctx = DecisionContext(node_status=gc.node_status(),
                          app={"queue_depth": queue, "slo_ms": slo_ms,
                               "max_batch": max_batch})
    ctx.profile = {"decode_ms_per_step": decode_ms}
    return ctx


def test_batching_admits_up_to_queue():
    assert batching_decision(_ctx(3)).scale == 3
    assert batching_decision(_ctx(20)).scale == 8


def test_batching_respects_slo():
    # 100ms SLO with 60ms/step: only one request is affordable
    d = batching_decision(_ctx(20, slo_ms=100.0, decode_ms=60.0))
    assert d.scale == 1


def test_engine_serves_all_requests(engine_setup):
    cfg, params = engine_setup
    engine = ServingEngine(cfg, params, max_batch=2, max_seq=48)
    rng = np.random.default_rng(0)
    for i in range(5):
        engine.submit(Request(i, rng.integers(0, 100, 6).tolist(),
                              max_new_tokens=3))
    done = engine.run(max_steps=256)
    assert len(done) == 5
    assert all(len(r.output) == 3 for r in done)
    assert engine.metrics["generated"] == 15


def test_engine_outputs_deterministic(engine_setup):
    cfg, params = engine_setup
    outs = []
    for _ in range(2):
        engine = ServingEngine(cfg, params, max_batch=1, max_seq=32)
        engine.submit(Request(0, [5, 6, 7, 8], max_new_tokens=4))
        done = engine.run(max_steps=64)
        outs.append(done[0].output)
    assert outs[0] == outs[1]


def test_engine_matches_offline_greedy(engine_setup):
    """Engine greedy decode == step-by-step forward greedy decode."""
    from repro.models.lm import forward

    cfg, params = engine_setup
    prompt = [3, 1, 4, 1, 5, 9]
    engine = ServingEngine(cfg, params, max_batch=1, max_seq=32)
    engine.submit(Request(0, list(prompt), max_new_tokens=3))
    got = engine.run(max_steps=64)[0].output

    seq = list(prompt)
    for _ in range(3):
        lg, _ = forward(params, {"tokens": jnp.asarray([seq], jnp.int32)},
                        cfg, remat="none", q_chunk=len(seq))
        seq.append(int(jnp.argmax(lg[0, -1])))
    assert got == seq[len(prompt):]


def test_engine_releases_slots(engine_setup):
    cfg, params = engine_setup
    engine = ServingEngine(cfg, params, max_batch=2, max_seq=32)
    for i in range(3):
        engine.submit(Request(i, [1, 2, 3], max_new_tokens=2))
    engine.run(max_steps=128)
    assert sum(engine.gc.used.values()) == 0
