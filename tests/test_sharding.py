"""Sharding rules, strategy decision nodes, and the HLO cost analyzer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.config import SHAPES, ParallelConfig, ShapeConfig
from repro.launch.hlo_analysis import analyze, split_computations
from repro.launch.mesh import make_smoke_mesh
from repro.parallel.sharding import ShardingRules, pad_to_multiple
from repro.parallel.strategies import (
    pick_attention_strategy,
    pick_moe_strategy,
    plan_cell,
)


class FakeMesh:
    """Shape-only stand-in so strategy tests don't build 512 devices."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.devices = np.empty(tuple(shape.values()), dtype=object)


SINGLE = FakeMesh({"data": 16, "model": 16})


# -- ShardingRules -----------------------------------------------------------


def test_spec_deduplicates_mesh_axes():
    rules = ShardingRules(None, {"seq": "model", "mlp": "model",
                                 "batch": "data"})
    spec = rules.spec("batch", "seq", "mlp")
    # second use of "model" must drop out (an axis can shard only one dim)
    assert spec == jax.sharding.PartitionSpec("data", "model", None)


def test_spec_handles_tuple_axes():
    rules = ShardingRules(None, {"batch": ("pod", "data")})
    assert rules.spec("batch", None) == jax.sharding.PartitionSpec(
        ("pod", "data"), None)


def test_pad_to_multiple():
    assert pad_to_multiple(151655, 128) == 151680
    assert pad_to_multiple(128, 128) == 128


# -- strategy decisions (the paper's decision tuple for LM cells) --------------


def test_attention_strategy_gqa_prefers_kv_broadcast():
    """GQA: broadcasting the small KV (hash-join move, 2*res + kv wire)
    beats classic Megatron head-TP (4*res wire) — the decision node picks
    seq_tp even though 32 heads divide the axis."""
    cfg = get_config("mistral-nemo-12b")      # 32H but kv=8 (tiny KV)
    assert pick_attention_strategy(cfg, SHAPES["train_4k"], 16) == "seq_tp"


def test_attention_strategy_mha_divisible_picks_head_tp():
    """MHA (kv == heads): the KV 'small table' isn't small, broadcast loses
    its edge; with divisible heads, head-TP wins the tie."""
    cfg = get_config("moonshot-v1-16b-a3b")   # 16H, kv=16, divisible
    assert pick_attention_strategy(cfg, SHAPES["train_4k"], 16) == "head_tp"


def test_attention_strategy_indivisible_heads_seq_tp():
    cfg = get_config("qwen1.5-4b")            # 20 heads: head_tp infeasible
    assert pick_attention_strategy(cfg, SHAPES["train_4k"], 16) == "seq_tp"


def test_attention_strategy_decode_uses_kv_shard():
    cfg = get_config("qwen2-72b")
    assert pick_attention_strategy(cfg, SHAPES["decode_32k"], 16) \
        == "decode_kv_shard"


def test_attention_strategy_attention_free():
    cfg = get_config("xlstm-1.3b")
    assert pick_attention_strategy(cfg, SHAPES["train_4k"], 16) == "none"


def test_moe_strategy_prefers_explicit_shuffle_for_training_tokens():
    cfg = get_config("moonshot-v1-16b-a3b")
    assert pick_moe_strategy(cfg, SHAPES["train_4k"], 16) == "shard_map_a2a"


def test_moe_strategy_prefers_gather_for_decode():
    cfg = get_config("granite-moe-1b-a400m")
    assert pick_moe_strategy(cfg, SHAPES["decode_32k"], 16) == "gather"


def test_plan_cell_resolves_everything():
    cfg = get_config("qwen2-72b")
    pc = plan_cell(cfg, SHAPES["train_4k"], SINGLE)
    assert pc.attn_strategy == "seq_tp"       # GQA kv=8: KV broadcast wins
    assert pc.fsdp in ("on", "off") and pc.fsdp == "on"   # 72B needs ZeRO
    assert pc.microbatches >= 1
    assert pc.sequence_sharded_residual is True


def test_plan_cell_small_model_no_fsdp():
    cfg = get_config("granite-moe-1b-a400m")
    pc = plan_cell(cfg, SHAPES["train_4k"], SINGLE)
    assert pc.fsdp == "off"


def test_plan_cell_respects_overrides():
    cfg = get_config("llama3.2-3b")
    pc = plan_cell(cfg, SHAPES["train_4k"], SINGLE,
                   ParallelConfig(attn_strategy="replicated",
                                  microbatches=4))
    assert pc.attn_strategy == "replicated"
    assert pc.microbatches == 4


# -- HLO analyzer --------------------------------------------------------------


def test_hlo_analyzer_multiplies_trip_counts():
    def body(c, x):
        return c @ x, ()

    def f(c, xs):
        return jax.lax.scan(body, c, xs)[0]

    c = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    for n in (4, 12):
        xs = jax.ShapeDtypeStruct((n, 64, 64), jnp.float32)
        compiled = jax.jit(f).lower(c, xs).compile()
        costs = analyze(compiled.as_text())
        assert costs.flops == pytest.approx(n * 2 * 64 ** 3, rel=1e-6)


def test_hlo_analyzer_matches_xla_on_straightline():
    """On a loop-free program the parser must agree with XLA's own count."""
    def f(a, b, c):
        return (a @ b) @ c

    spec = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    compiled = jax.jit(f).lower(spec, spec, spec).compile()
    from repro.compat import cost_analysis
    xla_flops = cost_analysis(compiled)["flops"]
    parsed = analyze(compiled.as_text()).flops
    assert parsed == pytest.approx(xla_flops, rel=1e-6)


def test_hlo_analyzer_nested_scans():
    def inner(c, x):
        return c @ x, ()

    def outer(c, xs):
        def step(c, _):
            c2, _ = jax.lax.scan(inner, c, xs)
            return c2, ()
        return jax.lax.scan(step, c, None, length=3)[0]

    c = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    xs = jax.ShapeDtypeStruct((5, 32, 32), jnp.float32)
    compiled = jax.jit(outer).lower(c, xs).compile()
    costs = analyze(compiled.as_text())
    assert costs.flops == pytest.approx(3 * 5 * 2 * 32 ** 3, rel=1e-6)


def test_split_computations_finds_entry():
    compiled = jax.jit(lambda x: x @ x).lower(
        jax.ShapeDtypeStruct((16, 16), jnp.float32)).compile()
    comps, entry = split_computations(compiled.as_text())
    assert entry in comps and comps
