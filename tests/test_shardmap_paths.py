"""Multi-device equivalence tests for the explicit shard_map data planes
(MoE all-to-all dispatch, int8 KV broadcast, sLSTM scan). These need >1
device, so they run in subprocesses with forced host devices."""

import os
import subprocess
import sys
import textwrap

import pytest

ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
       "XLA_FLAGS": "--xla_force_host_platform_device_count=8"}
if "JAX_PLATFORMS" in os.environ:   # keep the backend pin: plugin
    ENV["JAX_PLATFORMS"] = os.environ["JAX_PLATFORMS"]  # probing can hang


def run(script: str):
    result = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                            capture_output=True, text=True, timeout=600,
                            env=ENV)
    assert result.returncode == 0, result.stderr[-3000:]
    assert "OK" in result.stdout


@pytest.mark.slow
def test_moe_shard_map_matches_reference():
    run("""
    import dataclasses, jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.models.moe import init_moe, moe, moe_shard_map
    from repro.compat import set_mesh
    from repro.parallel.sharding import ShardingRules, use_rules

    cfg = get_config("granite-moe-1b-a400m", smoke=True)
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, num_experts=8, top_k=2, capacity_factor=8.0))
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    params, _ = init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                          jnp.float32)
    y_ref, _ = moe(params, x, cfg)
    rules = ShardingRules(mesh, {"batch": "data", "seq": None,
                                 "embed": None, "expert": "model",
                                 "w_embed": None,
                                 "moe_impl": "shard_map_a2a"})
    with set_mesh(mesh), use_rules(rules):
        y, _ = jax.jit(lambda p, x: moe_shard_map(p, x, cfg))(params, x)
        # gradients flow
        g = jax.jit(jax.grad(
            lambda p, x: jnp.sum(moe_shard_map(p, x, cfg)[0] ** 2)))(
            params, x)
    err = float(jnp.max(jnp.abs(y_ref - y)))
    assert err < 1e-4, err
    gn = float(jnp.linalg.norm(g["gate"]))
    assert gn > 0, "expert grads must flow through the a2a"
    print("OK", err, gn)
    """)


@pytest.mark.slow
def test_int8_kv_broadcast_close_and_differentiable():
    run("""
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.models.attention import init_attention, attention
    from repro.compat import set_mesh
    from repro.parallel.sharding import ShardingRules, use_rules

    cfg = get_config("qwen1.5-4b", smoke=True)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    params, _ = init_attention(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model),
                          jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(32)[None], (2, 32))
    base = {"batch": "data", "seq": "model", "kv_seq": None,
            "kv_rep": None, "heads": None, "qkv": None, "embed": None,
            "mlp_seq": None, "w_embed": None}

    def run_case(extra):
        rules = ShardingRules(mesh, {**base, **extra})
        with set_mesh(mesh), use_rules(rules):
            out = jax.jit(lambda p, x: attention(p, x, pos, cfg,
                                                 q_chunk=8))(params, x)
            g = jax.jit(jax.grad(lambda p, x: jnp.sum(
                attention(p, x, pos, cfg, q_chunk=8) ** 2)))(params, x)
        return out, g

    o0, g0 = run_case({})
    o1, g1 = run_case({"kv_compress": True, "causal_skip": True})
    err = float(jnp.max(jnp.abs(o0 - o1)))
    assert err < 0.05, err
    for k in ("wk", "wv"):
        n0 = float(jnp.linalg.norm(g0[k]))
        n1 = float(jnp.linalg.norm(g1[k]))
        assert abs(n0 - n1) / n0 < 0.05, (k, n0, n1)
    print("OK", err)
    """)


@pytest.mark.slow
def test_slstm_shard_map_matches_unsharded():
    run("""
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.models.xlstm import init_slstm, slstm
    from repro.compat import set_mesh
    from repro.parallel.sharding import ShardingRules, use_rules

    cfg = get_config("xlstm-1.3b", smoke=True)
    params, _ = init_slstm(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 24, cfg.d_model),
                          jnp.float32)
    ref = slstm(params, x, cfg)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rules = ShardingRules(mesh, {"batch": "data", "seq": None,
                                 "embed": None, "inner": None,
                                 "w_embed": None})
    with set_mesh(mesh), use_rules(rules):
        out = jax.jit(lambda p, x: slstm(p, x, cfg))(params, x)
    err = float(jnp.max(jnp.abs(ref - out)))
    assert err < 1e-3, err
    print("OK", err)
    """)


@pytest.mark.slow
def test_pipeline_parallel_matches_plain_train_step():
    from repro.compat import LEGACY_SHARD_MAP
    if LEGACY_SHARD_MAP:
        pytest.skip("pipeline needs shard_map partial-manual (axis_names) "
                    "mode; legacy auto= lowering lacks PartitionId support")
    run("""
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.core.config import OptimizerConfig, ParallelConfig, ShapeConfig
    from repro.models import init_lm
    from repro.parallel.pipeline import make_pp_train_step, pp_rules
    from repro.compat import set_mesh
    from repro.parallel.sharding import ShardingRules, use_rules
    from repro.training.train_step import make_train_step, _loss_fn
    from repro.training.optimizer import init_opt_state
    from repro.data import SyntheticSource

    cfg = get_config("mistral-nemo-12b", smoke=True)
    shape = ShapeConfig("pp", 32, 8, "train")
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    pc = ParallelConfig(microbatches=4, remat="none",
                        attn_strategy="replicated")
    rules = pp_rules(ShardingRules(mesh, {"batch": ("data",),
                                          "layers": None}))
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in
             SyntheticSource(cfg, shape, seed=0).batch(0).items()}
    with set_mesh(mesh), use_rules(rules):
        state = {"params": params, "opt": init_opt_state(params)}
        pp_step = jax.jit(make_pp_train_step(
            cfg, shape, OptimizerConfig(warmup_steps=0), pc, rules,
            q_chunk=32))
        st_pp, m_pp = pp_step(state, batch)
    ref_loss, _ = _loss_fn(params, batch, cfg,
                           ParallelConfig(remat="none"), q_chunk=32,
                           ssm_chunk=16)
    assert abs(float(m_pp["loss"]) - float(ref_loss)) < 2e-2
    plain = jax.jit(make_train_step(
        cfg, shape, OptimizerConfig(warmup_steps=0),
        ParallelConfig(microbatches=4, remat="none"), q_chunk=32))
    st_ref, _ = plain({"params": params, "opt": init_opt_state(params)},
                      batch)
    cos = []
    for a, b, p0 in zip(jax.tree.leaves(st_pp["params"]),
                        jax.tree.leaves(st_ref["params"]),
                        jax.tree.leaves(params)):
        da = (a - p0).astype(jnp.float32).ravel()
        db = (b - p0).astype(jnp.float32).ravel()
        n = float(jnp.linalg.norm(da) * jnp.linalg.norm(db))
        if n > 1e-12:
            cos.append(float(jnp.dot(da, db)) / n)
    assert min(cos) > 0.95, min(cos)
    print("OK", min(cos))
    """)
