"""Skew-adaptive exchange: the eighth decision node end to end.

The tentpole contract under test: shuffle writers feed an observed
per-bucket histogram + heavy-hitter sketch into ``profile_feedback``, the
``skew`` node binds on it *between* exchange and join (none / salted /
broadcast), and the mitigation stages it materializes are data-plane
invisible — the oracle result is identical for every forced mitigation,
the runtime and the simulator bind identical eight-node sequences, and
seeded fault plans recover through the mitigated DAG exactly like the
plain one. The salted path's quantized sub-join chunks must not fan the
compile cache (shape-class regression), and the skewed workload generator
must actually realize the Zipf law it promises.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.analytics import (
    QueryStrategy,
    execute_query_runtime,
    synth_query_tables,
)
from repro.analytics.planner import (
    build_query_workflow,
    plan_query_with_workflow,
    shuffle_skew_feedback,
    tail_stages,
)
from repro.analytics.query import zipf_weights
from repro.analytics.simulator import ClusterSim
from repro.core.controllers import GlobalController, PrivateController
from repro.core.decisions import (
    DataDist,
    Decision,
    Schedule,
    merge_hot_keys,
    skew_mitigation,
)
from repro.kernels import ops as kops
from repro.runtime import FaultInjector, FaultPlan, Runtime
from tests._hypothesis_compat import given, settings, st

STRATEGIES = ("static_merge", "static_hash", "dynamic", "dynamic_fig6")
EIGHT_NODES = ["scan", "join", "exchange", "skew", "aggregate",
               "pipeline", "elastic", "tiering"]


class FanoutStrategy(QueryStrategy):
    """Pin the join fan-out: small test tables bind scale=1, which the
    skew guard (rightly) treats as unsplittable — mitigation tests need a
    real bucket space."""

    def __init__(self, name: str, fanout: int):
        super().__init__(name)
        self.fanout = fanout

    def join_method(self, ctx):
        d = super().join_method(ctx)
        return Decision(d.func, self.fanout, d.schedule, extras=d.extras)


@pytest.fixture(scope="module")
def skewed_tables():
    return synth_query_tables(rows=1 << 14, dim_rows=1024, zipf=1.5, seed=3)


@pytest.fixture(scope="module")
def small_skewed_tables():
    return synth_query_tables(rows=4096, dim_rows=512, zipf=1.5, seed=3)


def _run(tables, strat="static_merge", fanout=8, force=None, plan=None,
         invoker="inline", pipeline=False, **wf_kw):
    fd, dd, ref = tables
    gc = GlobalController({n: 8 for n in range(4)})
    rt = Runtime(gc, invoker=invoker)
    if plan is not None:
        FaultInjector(plan).install(rt)
    strategy = FanoutStrategy(strat, fanout)
    wf = build_query_workflow(strategy, skew_force=force, **wf_kw)
    got, _ = execute_query_runtime(fd, dd, strategy, runtime=rt,
                                   workflow=wf, pipeline=pipeline,
                                   recovery="lineage")
    np.testing.assert_allclose(got, ref, atol=1e-3)
    assert sum(gc.used.values()) == 0
    return rt, wf.last_run


# -- workload generator: the law it promises is the law it draws -------------------


def test_zipf_workload_matches_requested_law():
    s, rows = 1.2, 1 << 15
    fd, _, _ = synth_query_tables(rows=rows, dim_rows=256, zipf=s, seed=9)
    keys = np.concatenate([np.asarray(t["key"])
                           for _, t in sorted(fd.partitions.items())])
    assert keys.size == rows
    ks = 2 * max(rows, 256)
    emp = np.bincount(keys, minlength=ks) / rows
    th = zipf_weights(ks, s)
    # the head of the law is where the mass (and the skew) lives: every
    # top-20 key's realized frequency sits within sampling noise of its
    # theoretical mass
    for k in range(20):
        tol = 6 * np.sqrt(th[k] * (1 - th[k]) / rows) + 1e-4
        assert abs(emp[k] - th[k]) < tol, (k, emp[k], th[k])
    # and the head dominates like Zipf(1.2) says it should
    assert emp[:20].sum() > 0.5 * th[:20].sum()


def test_heavy_hitters_route_about_half_the_mass():
    fd, _, _ = synth_query_tables(rows=1 << 14, dim_rows=256,
                                  heavy_hitters=4, seed=5)
    keys = np.concatenate([np.asarray(t["key"])
                           for _, t in sorted(fd.partitions.items())])
    _, counts = np.unique(keys, return_counts=True)
    top4 = np.sort(counts)[-4:].sum() / keys.size
    assert 0.42 < top4 < 0.58


def test_default_workload_byte_identical_without_skew_params():
    base = synth_query_tables(2048, 256, seed=1)
    skew = synth_query_tables(2048, 256, seed=1, zipf=0.0, heavy_hitters=0)
    for (na, ta), (nb, tb) in zip(sorted(base[0].partitions.items()),
                                  sorted(skew[0].partitions.items())):
        assert na == nb
        for c in ta.columns:
            np.testing.assert_array_equal(np.asarray(ta[c]),
                                          np.asarray(tb[c]))
    np.testing.assert_array_equal(base[2], skew[2])


# -- the pure mitigation rule ------------------------------------------------------


def test_rule_guards_empty_and_single_bucket():
    assert skew_mitigation((), ()) == ("none", (), 0, ())
    for force in (None, "none", "salted", "broadcast"):
        assert skew_mitigation((10_000,), (), force=force)[0] == "none"


def test_rule_balanced_and_small_histograms_stay_none():
    assert skew_mitigation((10, 12, 11, 9), ())[0] == "none"     # < min_rows
    assert skew_mitigation((2000, 2100, 1900, 2000), ())[0] == "none"


def test_rule_lopsided_without_hot_key_salts():
    rows = (24_000, 2000, 2000, 2000, 2000, 2000, 2000, 2000)
    func, heavy, salt, hot = skew_mitigation(rows, ())
    assert func == "salted" and hot == ()
    assert heavy == ((0, 24_000),)
    # salt = ceil(max/mean) clamped to [2, salt_cap]
    mean = sum(rows) / len(rows)
    assert salt == min(8, max(2, int(np.ceil(24_000 / mean))))


def test_rule_dominating_key_broadcasts():
    rows = (24_000, 2000, 2000, 2000, 2000, 2000, 2000, 2000)
    sketch = ((7, 20_000), (3, 100))
    func, heavy, salt, hot = skew_mitigation(rows, sketch)
    assert func == "broadcast" and salt >= 2   # shards the heavy reads too
    assert hot == (7,)                    # 100 rows is below hot_frac
    assert heavy == ((0, 24_000),)


def test_rule_force_pins_each_mitigation():
    rows = (2000, 2100, 1900, 2000)       # balanced: auto would say none
    assert skew_mitigation(rows, ((5, 900),), force="none")[0] == "none"
    func, heavy, salt, _ = skew_mitigation(rows, (), force="salted")
    assert func == "salted" and salt >= 2
    assert heavy == ((1, 2100),)          # argmax bucket, split anyway
    func, _, _, hot = skew_mitigation(rows, ((5, 900),), force="broadcast")
    assert func == "broadcast" and hot == (5,)     # 900 clears hot_frac
    func, _, _, hot = skew_mitigation(rows, ((5, 400), (9, 300), (2, 10)),
                                      force="broadcast")
    assert func == "broadcast" and hot == (5, 9)   # top-2 sketch fallback
    assert skew_mitigation(rows, (), force="broadcast")[0] == "none"


def test_rule_never_salts_more_than_half_the_buckets():
    rng = np.random.default_rng(0)
    for _ in range(50):
        n = int(rng.integers(2, 33))
        rows = tuple(int(r) for r in rng.integers(0, 10_000, size=n))
        _, heavy, _, _ = skew_mitigation(rows, ())
        assert len(heavy) <= n // 2       # >= 2x mean fits at most n/2 times


# -- sketch + salting kernels ------------------------------------------------------


def test_heavy_hitter_sketch_exact_and_deterministic():
    rng = np.random.default_rng(4)
    keys = np.concatenate([np.full(5000, 7), np.full(3000, 42),
                           rng.integers(0, 1 << 14, size=2000)])
    rng.shuffle(keys)
    import jax.numpy as jnp

    sk = kops.heavy_hitter_sketch(jnp.asarray(keys, jnp.int32))
    assert sk == kops.heavy_hitter_sketch(jnp.asarray(keys, jnp.int32))
    assert sk[0] == (7, int((keys == 7).sum()))
    assert sk[1] == (42, int((keys == 42).sum()))
    assert kops.heavy_hitter_sketch(jnp.asarray([], jnp.int32)) == ()


def test_merge_hot_keys_sums_and_orders():
    merged = merge_hot_keys([((1, 10), (2, 5)), ((2, 9), (3, 14))])
    assert merged == ((2, 14), (3, 14), (1, 10))     # ties: smaller key
    assert merge_hot_keys([((k, 1),) for k in range(20)], k=4) == \
        ((0, 1), (1, 1), (2, 1), (3, 1))


def test_salted_ranges_cover_disjoint_pow2_chunks():
    for total, salt in ((3662, 4), (1000, 8), (17, 2), (4096, 4)):
        ranges = kops.salted_ranges(total, salt)
        assert ranges[0][0] == 0 and ranges[-1][1] == total
        for (_, hi), (lo2, _) in zip(ranges, ranges[1:]):
            assert hi == lo2
        widths = {hi - lo for lo, hi in ranges}
        assert len(widths) <= 2           # full pow2 chunk + one remainder
        full = max(widths)
        assert full & (full - 1) == 0     # power of two
    assert kops.salted_ranges(0, 4) == ()


# -- end to end: every mitigation is oracle-equal and audited ----------------------


@pytest.mark.parametrize("force,expect", [(None, "broadcast"),
                                          ("none", "none"),
                                          ("salted", "salted"),
                                          ("broadcast", "broadcast")])
def test_forced_mitigations_oracle_equal(skewed_tables, force, expect):
    rt, run = _run(skewed_tables, force=force)
    assert [n for n, _ in run.sequence] == EIGHT_NODES
    skew_d = run.decisions["skew"]
    assert skew_d.func == expect
    stage_names = {r.name.split("/")[1] for r in rt.metrics.records}
    if expect == "salted":
        assert "salted_join" in stage_names
        assert skew_d.extra("salt", 0) >= 2 and skew_d.extra("heavy", ())
    elif expect == "broadcast":
        # a broadcast split also writer-shards the hot buckets' reads
        assert {"hot_build", "hot_join", "salted_join"} <= stage_names
        assert skew_d.extra("hot_keys", ())
        assert skew_d.extra("salt", 0) >= 2
    else:
        assert not {"salted_join", "hot_build", "hot_join"} & stage_names


def test_auto_policy_uniform_stays_none():
    tables = synth_query_tables(rows=1 << 14, dim_rows=1024, seed=3)
    _, run = _run(tables)
    assert run.decisions["skew"].func == "none"
    assert run.decisions["skew"].extra("ratio", 0.0) < 2.0


def test_pipelined_executor_runs_mitigated_plans(skewed_tables):
    for force in ("salted", "broadcast"):
        _run(skewed_tables, force=force, pipeline=True, invoker="threads")


@pytest.mark.parametrize("force", ["salted", "broadcast"])
def test_mitigated_plans_on_process_backend(small_skewed_tables, force):
    """Writer-restricted sub-join reads must survive the worker RPC: the
    ``writers=`` subset travels inside the get message and the host
    services it against the per-writer blob map (regression: the new kwarg
    once broke every process-backend read)."""
    fd, dd, ref = small_skewed_tables
    gc = GlobalController({n: 8 for n in range(4)})
    rt = Runtime(gc, invoker="process", max_workers=2)
    try:
        strategy = FanoutStrategy("static_merge", 8)
        wf = build_query_workflow(strategy, skew_force=force)
        got, _ = execute_query_runtime(fd, dd, strategy, runtime=rt,
                                       workflow=wf, pipeline=True)
        np.testing.assert_allclose(got, ref, atol=1e-3)
        assert wf.last_run.decisions["skew"].func == force
        stage_names = {r.name.split("/")[1] for r in rt.metrics.records}
        assert "salted_join" in stage_names
    finally:
        rt.invoker.shutdown()


def test_observed_feedback_reaches_profile_and_tracer(skewed_tables):
    from repro.obs.tracer import Tracer, set_tracer

    prev = set_tracer(Tracer())
    try:
        _, run = _run(skewed_tables)
        tracks = {t for _, t, _, _ in
                  __import__("repro.obs.tracer",
                             fromlist=["get_tracer"]).get_tracer().counters()}
    finally:
        set_tracer(prev)
    rows = run.ctx.profile["skew.partition_rows"]
    nbytes = run.ctx.profile["skew.partition_bytes"]
    hot = run.ctx.profile["skew.hot_keys"]
    assert len(rows) == 8 and len(nbytes) == 8
    assert sum(rows) > 0 and hot and hot[0][1] >= hot[-1][1]
    assert {"skew/query/max_partition_bytes",
            "skew/query/mean_partition_bytes",
            "skew/query/hot_keys"} <= tracks


# -- cross-plane parity: the sim materializes the same skew decision ---------------


@pytest.mark.parametrize("force", [None, "salted"])
def test_skew_decision_parity_across_planes(small_skewed_tables, force):
    fd, dd, ref = small_skewed_tables
    strategy = FanoutStrategy("dynamic", 8)
    wf = build_query_workflow(strategy, skew_force=force)

    gc_rt = GlobalController({n: 8 for n in range(4)})
    rt = Runtime(gc_rt)
    got, _ = execute_query_runtime(fd, dd, strategy, runtime=rt,
                                   workflow=wf)
    np.testing.assert_allclose(got, ref, atol=1e-3)
    seq_rt = [(s, d.func, d.scale, d.extras) for s, d in
              wf.last_run.sequence]

    gc_sim = GlobalController({n: 8 for n in range(4)})
    sim = ClusterSim(gc_sim)
    pc = PrivateController("query", gc_sim, priority=10)
    plan_query_with_workflow(sim, pc, fd, dd, strategy, workflow=wf)
    sim.run()
    seq_sim = [(s, d.func, d.scale, d.extras) for s, d in
               wf.last_run.sequence]

    assert [s for s, *_ in seq_rt] == EIGHT_NODES
    assert seq_rt == seq_sim        # heavy buckets / salt / hot keys too


def test_sim_feedback_recomputes_runtime_histogram(skewed_tables):
    """The simulator's stand-in histogram is *exactly* the runtime's
    observed one — same kernels over the same partitions."""
    fd, dd, _ = skewed_tables
    rows, nbytes, hot = shuffle_skew_feedback(fd, 8)
    _, run = _run(skewed_tables)
    assert run.ctx.profile["skew.partition_rows"] == rows
    assert run.ctx.profile["skew.partition_bytes"] == nbytes
    assert run.ctx.profile["skew.hot_keys"] == hot


# -- mitigation stages carry sound needs edges -------------------------------------


def _mitigated_stages(skew):
    join_d = Decision("merge_join", 4, Schedule("round-robin", (0, 1)))
    return {s.name: s for s in tail_stages(
        "q", [(0, 0), (1, 1)], [(0, 0)], join_d,
        DataDist("A", {0: 1 << 20}),
        exchange=Decision("shuffle", 4, Schedule("round-robin", (0, 1))),
        skew=skew)}


def test_salted_stage_needs_edges():
    skew = Decision("salted", 4, Schedule("round-robin", (0, 1)),
                    extras=(("heavy", ((1, 9000),)), ("salt", 2),
                            ("hot_keys", ())))
    stages = _mitigated_stages(skew)
    fact_writers = {"q/shuffle_fact/0", "q/shuffle_fact/1"}
    # the heavy bucket is handed to the sub-joins; plain join skips it
    assert [iv.index for iv in stages["join"].invocations] == [0, 2, 3]
    subs = stages["salted_join"].invocations
    assert len(subs) == 2
    groups = []
    for iv in subs:
        group = set(iv.params["fact_writers"])
        groups.append(group)
        # per-shard needs: this shard's fact writers + the whole dim side
        assert set(iv.needs) == group | {"q/shuffle_dim/0"}
        assert iv.params["fact_partitions"] == [1]
        # shard outputs are extra joined partitions past the join fan-out
        assert iv.params["dst"] == "joined" and iv.params["partition"] >= 4
    # shards partition the writer set: disjoint, covering
    assert groups[0] & groups[1] == set()
    assert groups[0] | groups[1] == fact_writers
    # buckets now outlive the join stage: partial_agg reclaims them
    assert stages["join"].ephemeral_inputs == ()
    assert set(stages["partial_agg"].ephemeral_inputs) >= \
        {"joined", "fact_buckets", "dim_buckets"}
    agg = {iv.index: iv for iv in stages["partial_agg"].invocations}
    assert 1 not in agg            # no joined[1] exists to aggregate
    assert agg[0].needs == ("q/join/0",)
    assert agg[4].needs == ("q/salted_join/0",)
    assert agg[5].needs == ("q/salted_join/1",)


def test_broadcast_shards_hot_bucket_reads():
    skew = Decision("broadcast", 2, Schedule("round-robin", (0, 1)),
                    extras=(("heavy", ((1, 9000),)), ("salt", 2),
                            ("hot_keys", (3, 11))))
    stages = _mitigated_stages(skew)
    hot_buckets = {int(b) for b in np.asarray(
        kops.partition_ids(np.asarray((3, 11), np.int32), 4))}
    # the hot buckets leave the plain join for the writer-sharded sub-joins
    assert {iv.index for iv in stages["join"].invocations} == \
        set(range(4)) - hot_buckets
    subs = stages["salted_join"].invocations
    assert len(subs) == 2 * len(hot_buckets)
    for iv in subs:
        assert tuple(iv.params["drop_keys"]) == (3, 11)
        # shard ids start past the hot_join probes (n_join + n_fact)
        assert iv.params["dst"] == "joined" and iv.params["partition"] >= 6
    agg_parts = {iv.index for iv in stages["partial_agg"].invocations}
    assert agg_parts == (set(range(4)) - hot_buckets) | {4, 5} | \
        {6 + i for i in range(len(subs))}


def test_broadcast_stage_needs_edges():
    skew = Decision("broadcast", 2, Schedule("round-robin", (0, 1)),
                    extras=(("heavy", ()), ("salt", 0),
                            ("hot_keys", (3, 11))))
    stages = _mitigated_stages(skew)
    build, = stages["hot_build"].invocations
    assert set(build.needs) == {"q/scan_dim/0"}
    assert tuple(build.params["keys"]) == (3, 11)
    hot = {iv.index: iv for iv in stages["hot_join"].invocations}
    assert set(hot) == {0, 1}
    for i, iv in hot.items():
        assert set(iv.needs) == {f"q/scan_fact/{i}", "q/hot_build/0"}
        assert iv.params["partition"] == 4 + i   # appended after n_join
    # the buckets holding the hot keys drop them from the plain join
    hot_buckets = {int(b) for b in np.asarray(
        kops.partition_ids(np.asarray((3, 11), np.int32), 4))}
    for iv in stages["join"].invocations:
        assert ("drop_keys" in iv.params) == (iv.index in hot_buckets)
    agg_parts = {iv.index for iv in stages["partial_agg"].invocations}
    assert agg_parts == {0, 1, 2, 3, 4, 5}
    assert "dim_hot" in stages["partial_agg"].ephemeral_inputs


# -- compile-cache discipline under salting ----------------------------------------


def test_salted_run_does_not_fan_the_compile_cache(skewed_tables):
    _run(skewed_tables, force="salted")        # warm every shape once
    classes = kops.shape_class_count()
    cache = kops.grouping_cache_size()
    _run(skewed_tables, force="salted")
    assert kops.shape_class_count() == classes
    got = kops.grouping_cache_size()
    assert got == -1 or got == cache           # -1: jax internals moved


# -- invariance: mitigation survives seeded fault schedules ------------------------


_BASELINE: dict = {}


def _fault_view(rt, run):
    return {
        "sequence": [(n, d.func, d.scale) for n, d in run.sequence],
        "skew_extras": run.decisions["skew"].extras,
        # a set: recovery re-executes producers, so an invocation can
        # commit more than once — what must not change is *which* ones do
        "ok_invs": sorted(
            {r.name for r in rt.metrics.records if r.status == "ok"}),
    }


def _check_fault_invariance(small_skewed_tables, strat, force, seed):
    """For any strategy x forced mitigation, a seeded crash+loss schedule
    changes *nothing* the control plane audits: same eight decisions (skew
    extras included), same set of committed invocations (retries add
    records, not commits), same oracle-equal result."""
    key = (strat, force)
    if key not in _BASELINE:
        rt, run = _run(small_skewed_tables, strat=strat, force=force)
        _BASELINE[key] = _fault_view(rt, run)
    plan = FaultPlan.seeded(seed, stages=("shuffle_fact", "join"),
                            data_stages=("joined", "fact_buckets"),
                            delay=0.01)
    rt, run = _run(small_skewed_tables, strat=strat, force=force,
                   plan=plan, invoker="threads")
    assert _fault_view(rt, run) == _BASELINE[key]


@pytest.mark.parametrize("strat,force,seed", [
    ("static_merge", "salted", 7),
    ("dynamic", "broadcast", 7),
    ("static_hash", "none", 3),
])
def test_mitigation_invariant_under_pinned_faults(small_skewed_tables,
                                                  strat, force, seed):
    """Deterministic anchor of the property below — runs even where
    hypothesis is not installed."""
    _check_fault_invariance(small_skewed_tables, strat, force, seed)


@settings(deadline=None, max_examples=10)
@given(strat=st.sampled_from(STRATEGIES),
       force=st.sampled_from(("none", "salted", "broadcast")),
       seed=st.integers(0, 5))
def test_mitigation_invariant_under_seeded_faults(small_skewed_tables,
                                                  strat, force, seed):
    _check_fault_invariance(small_skewed_tables, strat, force, seed)
