"""Property-based invariants for the shuffle store + quota machinery.

The hypothesis suite drives random interleavings of put / retry-overwrite /
delete_stage / clear_app / seal / get against a model and checks the store's
accounting invariants hold at every step:

  * ``resident_bytes`` equals the live blob bytes per node, never negative
  * ``app_bytes`` equals the live blob bytes per app, never negative
  * ``read_bytes`` / ``sent_bytes`` / ``cross_node_bytes`` are conserved:
    every byte a reader is charged was either local or counted exactly once
    against its source node's ``sent_bytes`` and the global cross-node total

The base interleaving suite runs once per *primary* storage backend
(memory / disk / emulated object store — accounting is medium-agnostic),
and a tiered variant adds demote (spill), promote-on-read, and stage-loss
operations with per-tier byte conservation and tombstone invariants.

The quota tests (plain pytest, always run) cover eviction of sealed stages,
blocking admission backpressure, the timeout error, and a whole query
executing under a quota with peak-footprint bounding, plus regressions for
batch-write atomicity, eviction targeting, and replace-path admission.
"""

import threading
import time

import pytest

from tests._hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.runtime import (DiskBackend, ObjectStoreBackend,
                           QuotaExceededError, ShuffleStore, StageLostError)


class FakeTable:
    """Duck-typed stand-in: the store only touches nbytes/num_rows/concat."""

    def __init__(self, nbytes: int, rows: int):
        self.nbytes = nbytes
        self.num_rows = rows

    def concat(self, other: "FakeTable") -> "FakeTable":
        return FakeTable(self.nbytes + other.nbytes,
                         self.num_rows + other.num_rows)


APPS = ("a", "b")
STAGES = ("s0", "s1")
WRITERS = ("w0", "w1")
NODES = (0, 1, 2)

op_put = st.tuples(st.just("put"), st.sampled_from(APPS),
                   st.sampled_from(STAGES), st.integers(0, 2),
                   st.sampled_from(WRITERS), st.integers(1, 100),
                   st.sampled_from(NODES))
op_delete = st.tuples(st.just("delete"), st.sampled_from(APPS),
                      st.sampled_from(STAGES))
op_clear = st.tuples(st.just("clear"), st.sampled_from(APPS))
op_seal = st.tuples(st.just("seal"), st.sampled_from(APPS),
                    st.sampled_from(STAGES))
op_get = st.tuples(st.just("get"), st.sampled_from(APPS),
                   st.sampled_from(STAGES), st.integers(0, 2),
                   st.sampled_from(NODES))
ops_strategy = st.lists(st.one_of(op_put, op_delete, op_clear, op_seal,
                                  op_get),
                        max_size=80)

# primary backends the base suite must hold on identically: accounting is
# medium-agnostic, only the payload's resting place differs
BACKENDS = ("memory", "disk", "object")


def _make_store(backend: str, **kw) -> ShuffleStore:
    """A store whose *primary* tier is ``backend``. The object tier is
    built with zeroed latency/bandwidth/cost so property runs stay
    instantaneous; disk uses a real tempdir (closed by the caller)."""
    if backend == "object":
        return ShuffleStore(backend=ObjectStoreBackend(
            latency_s=0.0, bw=None, cost_per_request=0.0, cost_per_gb=0.0),
            **kw)
    return ShuffleStore(backend=backend, **kw)


@pytest.mark.parametrize("backend", BACKENDS)
def test_store_accounting_invariants_under_interleavings(backend):
    @settings(deadline=None)
    @given(ops=ops_strategy)
    def prop(ops):
        store = _make_store(backend)
        try:
            _check_accounting_interleaving(store, ops)
        finally:
            store.close()

    prop()


def _check_accounting_interleaving(store: ShuffleStore, ops) -> None:
    # model: (app, stage) -> partition -> writer -> (nbytes, node)
    model: dict = {}
    total_read = 0          # every byte charged to any reader
    total_remote = 0        # the subset that crossed nodes

    for op in ops:
        if op[0] == "put":
            _, app, stage, part, writer, nbytes, node = op
            store.put(app, stage, part, FakeTable(nbytes, 1), node,
                      writer=writer)
            model.setdefault((app, stage), {}).setdefault(
                part, {})[writer] = (nbytes, node)
        elif op[0] == "delete":
            _, app, stage = op
            freed = store.delete_stage(app, stage)
            parts = model.pop((app, stage), {})
            assert freed == sum(b for blobs in parts.values()
                                for b, _ in blobs.values())
        elif op[0] == "clear":
            _, app = op
            freed = store.clear_app(app)
            expect = 0
            for key in [k for k in model if k[0] == app]:
                expect += sum(b for blobs in model.pop(key).values()
                              for b, _ in blobs.values())
            assert freed == expect
        elif op[0] == "seal":
            _, app, stage = op
            store.seal(app, stage)    # no quota: marker only, bytes stay
        else:
            _, app, stage, part, reader = op
            got = store.get(app, stage, part, node=reader)
            blobs = model.get((app, stage), {}).get(part, {})
            if not blobs:
                assert got is None
            else:
                assert got.nbytes == sum(b for b, _ in blobs.values())
                total_read += got.nbytes
                total_remote += sum(b for b, n in blobs.values()
                                    if n != reader)

        # -- invariants after every operation ---------------------------------
        live_per_node: dict[int, int] = {}
        live_per_app: dict[str, int] = {}
        for (app_k, _), parts in model.items():
            for blobs in parts.values():
                for b, n in blobs.values():
                    live_per_node[n] = live_per_node.get(n, 0) + b
                    live_per_app[app_k] = live_per_app.get(app_k, 0) + b
        assert all(v >= 0 for v in store.resident_bytes.values())
        assert {n: v for n, v in store.resident_bytes.items() if v} == \
            live_per_node
        assert all(v >= 0 for v in store.app_bytes.values())
        assert {a: v for a, v in store.app_bytes.items() if v} == \
            live_per_app
        # conservation: reader charges == model reads; remote subset appears
        # once in the source's sent_bytes and once in the global total
        assert sum(store.read_bytes.values()) == total_read
        assert sum(store.sent_bytes.values()) == total_remote
        assert store.cross_node_bytes == total_remote


# -- tiered interleavings: demotion / promotion / loss ------------------------

TIERS = ("disk", "object")

op_demote = st.tuples(st.just("demote"), st.sampled_from(APPS),
                      st.sampled_from(STAGES), st.sampled_from(TIERS))
op_lose = st.tuples(st.just("lose"), st.sampled_from(APPS),
                    st.sampled_from(STAGES))
tier_ops_strategy = st.lists(st.one_of(op_put, op_delete, op_seal, op_get,
                                       op_demote, op_lose),
                             max_size=80)


def _make_tiered_store() -> ShuffleStore:
    return ShuffleStore(spill_backends=[
        DiskBackend(),
        ObjectStoreBackend(latency_s=0.0, bw=None,
                           cost_per_request=0.0, cost_per_gb=0.0)])


@settings(deadline=None)
@given(ops=tier_ops_strategy)
def test_tiered_invariants_across_demote_promote_interleavings(ops):
    """Byte conservation, quota accounting, and tombstone invariants hold
    across arbitrary interleavings of writes, spills to colder tiers,
    promote-on-read (no quota: every cold read promotes), stage loss, and
    teardown: hot bytes live in resident/app accounting, cold bytes in
    ``tier_bytes``, and every blob is in exactly one of the two."""
    store = _make_tiered_store()
    try:
        _check_tiered_interleaving(store, ops)
    finally:
        store.close()


def _check_tiered_interleaving(store: ShuffleStore, ops) -> None:
    try:
        # model: (app, stage) -> part -> writer -> (nbytes, node, tier)
        model: dict = {}
        lost: dict = {}          # (app, stage) -> tombstoned partition ids
        total_read = 0
        total_remote = 0
        for op in ops:
            if op[0] == "put":
                _, app, stage, part, writer, nbytes, node = op
                store.put(app, stage, part, FakeTable(nbytes, 1), node,
                          writer=writer)
                model.setdefault((app, stage), {}).setdefault(
                    part, {})[writer] = (nbytes, node, "memory")
                lost.get((app, stage), set()).discard(part)   # put heals
            elif op[0] == "delete":
                _, app, stage = op
                freed = store.delete_stage(app, stage)
                parts = model.pop((app, stage), {})
                lost.pop((app, stage), None)
                assert freed == sum(b for blobs in parts.values()
                                    for b, _, _ in blobs.values())
            elif op[0] == "seal":
                _, app, stage = op
                store.seal(app, stage)
            elif op[0] == "demote":
                _, app, stage, tier = op
                hot = sum(b for blobs in model.get((app, stage), {}).values()
                          for b, _, t in blobs.values() if t == "memory")
                freed = store.demote_stage(app, stage, tier)
                assert freed == hot      # only hot blobs spill
                for blobs in model.get((app, stage), {}).values():
                    for w, (b, n, t) in list(blobs.items()):
                        if t == "memory":
                            blobs[w] = (b, n, tier)
            elif op[0] == "lose":
                _, app, stage = op
                freed = store.lose_stage(app, stage)
                parts = model.pop((app, stage), {})
                # loss frees hot AND cold payloads (a lost spilled stage
                # recovers via lineage like any other)
                assert freed == sum(b for blobs in parts.values()
                                    for b, _, _ in blobs.values())
                if parts:
                    lost.setdefault((app, stage), set()).update(parts)
            else:   # get
                _, app, stage, part, reader = op
                blobs = model.get((app, stage), {}).get(part, {})
                if not blobs and part in lost.get((app, stage), set()):
                    with pytest.raises(StageLostError):
                        store.get(app, stage, part, node=reader)
                else:
                    got = store.get(app, stage, part, node=reader)
                    if not blobs:
                        assert got is None
                    else:
                        assert got.nbytes == \
                            sum(b for b, _, _ in blobs.values())
                        total_read += got.nbytes
                        # only hot blobs are node-to-node traffic; cold
                        # reads are backend traffic
                        total_remote += sum(b for b, n, t in blobs.values()
                                            if t == "memory" and n != reader)
                        # no quota: every cold slice read promotes to hot
                        for w, (b, n, t) in list(blobs.items()):
                            blobs[w] = (b, n, "memory")

            # -- invariants after every operation -----------------------------
            hot_per_node: dict = {}
            hot_per_app: dict = {}
            cold: dict = {}      # tier -> app -> bytes
            for (app_k, _), parts in model.items():
                for blobs in parts.values():
                    for b, n, t in blobs.values():
                        if t == "memory":
                            hot_per_node[n] = hot_per_node.get(n, 0) + b
                            hot_per_app[app_k] = \
                                hot_per_app.get(app_k, 0) + b
                        else:
                            per = cold.setdefault(t, {})
                            per[app_k] = per.get(app_k, 0) + b
            assert all(v >= 0 for v in store.resident_bytes.values())
            assert {n: v for n, v in store.resident_bytes.items() if v} == \
                hot_per_node
            assert {a: v for a, v in store.app_bytes.items() if v} == \
                hot_per_app
            assert all(v >= 0 for per in store.tier_bytes.values()
                       for v in per.values())
            got_cold = {t: {a: v for a, v in per.items() if v}
                        for t, per in store.tier_bytes.items()}
            assert {t: per for t, per in got_cold.items() if per} == cold
            assert sum(store.read_bytes.values()) == total_read
            assert sum(store.sent_bytes.values()) == total_remote
            assert store.cross_node_bytes == total_remote
            for key_k, parts_k in lost.items():
                assert store.lost_partitions(*key_k) == parts_k
    finally:
        store.close()


@settings(deadline=None)
@given(ops=st.lists(st.tuples(st.sampled_from(WRITERS), st.integers(1, 50)),
                    min_size=1, max_size=20))
def test_retry_overwrite_keeps_resident_at_last_write(ops):
    """Repeated retry-overwrites of one partition: resident bytes equal the
    sum of each writer's *last* slice, regardless of the retry history."""
    store = ShuffleStore()
    last: dict[str, int] = {}
    for writer, nbytes in ops:
        store.put("app", "s", 0, FakeTable(nbytes, 1), node=0, writer=writer)
        last[writer] = nbytes
    assert store.resident_bytes[0] == sum(last.values())
    assert store.app_bytes["app"] == sum(last.values())
    assert store.written_bytes[0] == sum(n for _, n in ops)


# -- quota machinery (always run) -------------------------------------------------


def test_quota_put_evicts_sealed_stage_lru():
    store = ShuffleStore(quotas={"app": 100})
    store.put("app", "old1", 0, FakeTable(40, 1), node=0, writer="w")
    store.put("app", "old2", 0, FakeTable(40, 1), node=0, writer="w")
    store.seal("app", "old1")
    store.seal("app", "old2")
    # 30 more bytes do not fit 100: the LRU sealed stage (old1) is evicted
    store.put("app", "new", 0, FakeTable(30, 1), node=0, writer="w")
    # evicted-but-was-written data reads as a typed loss (recoverable via
    # lineage), never as silently-absent None
    with pytest.raises(StageLostError):
        store.get("app", "old1", 0, node=0)
    assert store.get("app", "old2", 0, node=0) is not None
    assert store.app_bytes["app"] == 70
    assert store.evictions == [("app", "old1", 40)]
    assert store.peak_bytes["app"] <= 100


def test_sealed_stage_remains_readable_until_evicted():
    store = ShuffleStore(quotas={"app": 1000})
    store.put("app", "s", 0, FakeTable(10, 1), node=0, writer="w")
    store.seal("app", "s")
    assert store.get("app", "s", 0, node=0).nbytes == 10


def test_quota_blocks_until_concurrent_free():
    store = ShuffleStore(quotas={"app": 100}, quota_timeout=5.0)
    store.put("app", "held", 0, FakeTable(90, 1), node=0, writer="w")

    def free_later():
        time.sleep(0.1)
        store.delete_stage("app", "held")

    t = threading.Thread(target=free_later)
    t.start()
    t0 = time.monotonic()
    store.put("app", "next", 0, FakeTable(50, 1), node=0, writer="w")
    waited = time.monotonic() - t0
    t.join()
    assert waited >= 0.05            # it really blocked for the free
    assert store.app_bytes["app"] == 50


def test_oversized_write_fails_fast_without_timeout():
    """A blob bigger than the quota itself can never be admitted: it must
    raise immediately, not pin the writer for quota_timeout seconds."""
    store = ShuffleStore(quotas={"app": 100}, quota_timeout=10.0)
    t0 = time.monotonic()
    with pytest.raises(QuotaExceededError, match="can never fit"):
        store.put("app", "s", 0, FakeTable(101, 1), node=0, writer="w")
    assert time.monotonic() - t0 < 1.0


def test_quota_timeout_raises():
    store = ShuffleStore(quotas={"app": 100}, quota_timeout=0.05)
    store.put("app", "held", 0, FakeTable(90, 1), node=0, writer="w")
    with pytest.raises(QuotaExceededError):
        store.put("app", "next", 0, FakeTable(50, 1), node=0, writer="w")
    # the held stage is untouched, the failed write landed nothing
    assert store.app_bytes["app"] == 90


def test_quota_retry_overwrite_charges_delta_not_sum():
    store = ShuffleStore(quotas={"app": 100}, quota_timeout=0.05)
    store.put("app", "s", 0, FakeTable(80, 1), node=0, writer="w")
    # a retried invocation replaces its slice: 90 fits because 80 retracts
    store.put("app", "s", 0, FakeTable(90, 1), node=0, writer="w")
    assert store.app_bytes["app"] == 90
    assert store.peak_bytes["app"] == 90


def test_put_many_refused_batch_commits_nothing():
    """Regression: a quota refusal mid-batch must not leave the earlier
    partitions of the batch committed — admission covers the batch *total*
    up front, so a failed ``put_many`` is invisible (no partial commits,
    no tombstones, accounting untouched)."""
    store = ShuffleStore(quotas={"app": 100}, quota_timeout=0.05)
    store.put("app", "held", 0, FakeTable(60, 1), node=0, writer="w")
    with pytest.raises(QuotaExceededError):
        # 30 + 30 = 60 > the 40 bytes of headroom; per-slice admission
        # would commit partition 0 before failing on partition 1
        store.put_many("app", "batch", {0: FakeTable(30, 1),
                                        1: FakeTable(30, 1)},
                       node=0, writer="w")
    assert store.partitions("app", "batch") == []
    assert store.lost_partitions("app", "batch") == set()
    assert store.app_bytes["app"] == 60
    assert store.resident_bytes[0] == 60


def test_put_many_oversized_batch_fails_fast():
    """A batch whose total can never fit fails fast even though every
    individual slice would fit — no trickle-in, no quota_timeout pin."""
    store = ShuffleStore(quotas={"app": 100}, quota_timeout=10.0)
    t0 = time.monotonic()
    with pytest.raises(QuotaExceededError, match="can never fit"):
        store.put_many("app", "batch", {0: FakeTable(60, 1),
                                        1: FakeTable(60, 1)},
                       node=0, writer="w")
    assert time.monotonic() - t0 < 1.0
    assert store.partitions("app", "batch") == []
    assert store.app_bytes.get("app", 0) == 0


def test_eviction_never_targets_the_write_destination():
    """Regression: a sealed-then-rewritten stage must not evict *itself*
    to admit the new slice — that would tombstone peer writers' committed
    partitions of the very stage being written. With nothing else sealed
    the write times out; the destination's data survives untouched."""
    store = ShuffleStore(quotas={"app": 100}, quota_timeout=0.05)
    store.put("app", "dest", 0, FakeTable(80, 1), node=0, writer="w0")
    store.seal("app", "dest")          # consumed once, now being rewritten
    with pytest.raises(QuotaExceededError):
        store.put("app", "dest", 1, FakeTable(40, 1), node=0, writer="w1")
    assert store.evictions == []
    assert store.lost_partitions("app", "dest") == set()
    assert store.get("app", "dest", 0, node=0).nbytes == 80


def test_eviction_reclaims_other_sealed_stage_not_destination():
    store = ShuffleStore(quotas={"app": 100}, quota_timeout=0.05)
    store.put("app", "other", 0, FakeTable(50, 1), node=0, writer="w")
    store.put("app", "dest", 0, FakeTable(30, 1), node=0, writer="w")
    store.seal("app", "other")
    store.seal("app", "dest")
    # 40 more bytes need 20 of headroom: "other" is evicted, never "dest"
    store.put("app", "dest", 1, FakeTable(40, 1), node=0, writer="w")
    assert store.evictions == [("app", "other", 50)]
    assert store.get("app", "dest", 0, node=0).nbytes == 30
    assert store.app_bytes["app"] == 70


def test_admit_fail_fast_reports_write_size_and_net_delta():
    """Regression: the fail-fast error used to report only the raw write
    size; on the replace path the *net delta* (after retracting the
    replaced slice) is what the quota actually refused. Both appear."""
    store = ShuffleStore(quotas={"app": 100}, quota_timeout=10.0)
    store.put("app", "s", 0, FakeTable(40, 1), node=0, writer="w")
    t0 = time.monotonic()
    with pytest.raises(QuotaExceededError, match="can never fit") as ei:
        store.put("app", "s", 0, FakeTable(150, 1), node=0, writer="w")
    assert time.monotonic() - t0 < 1.0
    msg = str(ei.value)
    assert "150" in msg and "110" in msg     # raw size and net delta
    # the refused replace left the original slice in place
    assert store.app_bytes["app"] == 40
    assert store.get("app", "s", 0, node=0).nbytes == 40


def test_replace_admitted_on_delta_when_nbytes_exceeds_quota():
    """The replace path admits on the net delta: a shrinking rewrite is
    admitted instantly even though its raw size exceeds the quota and the
    app is already over the cap (lowered after the original write)."""
    store = ShuffleStore(quota_timeout=0.05)
    store.put("app", "s", 0, FakeTable(150, 1), node=0, writer="w")
    store.set_quota("app", 100)
    # delta is -30: admitted without blocking, raising, or evicting
    store.put("app", "s", 0, FakeTable(120, 1), node=0, writer="w")
    assert store.app_bytes["app"] == 120
    assert store.peak_bytes["app"] == 150
    assert store.evictions == []


def test_quota_is_per_app():
    store = ShuffleStore(quotas={"a": 50}, quota_timeout=0.05)
    store.put("a", "s", 0, FakeTable(50, 1), node=0, writer="w")
    # app b is uncapped; app a is at its limit
    store.put("b", "s", 0, FakeTable(500, 1), node=0, writer="w")
    with pytest.raises(QuotaExceededError):
        store.put("a", "s2", 0, FakeTable(1, 1), node=0, writer="w")


def test_reclaim_stage_seals_under_quota_deletes_otherwise():
    quota = ShuffleStore(quotas={"app": 1000})
    quota.put("app", "s", 0, FakeTable(10, 1), node=0, writer="w")
    assert quota.reclaim_stage("app", "s") == 0          # sealed, not freed
    assert quota.app_bytes["app"] == 10
    plain = ShuffleStore()
    plain.put("app", "s", 0, FakeTable(10, 1), node=0, writer="w")
    assert plain.reclaim_stage("app", "s") == 10         # dropped now
    assert plain.app_bytes["app"] == 0


def test_query_completes_under_quota_with_bounded_peak():
    """A full query under a per-app quota equal to its unconstrained peak:
    ephemeral stages get sealed instead of dropped, quota pressure evicts
    them, the result stays oracle-correct and the live footprint never
    exceeds the cap."""
    import jax.numpy as jnp
    import numpy as np

    from repro.analytics import (
        QueryStrategy,
        Table,
        execute_query_runtime,
        reference_query_numpy,
        synth_table,
    )
    from repro.analytics.table import distribute
    from repro.core.controllers import GlobalController
    from repro.runtime import Runtime

    fact = synth_table("f", 4096, 2048, seed=21)
    dimc = synth_table("d", 512, 2048, seed=22, unique_keys=True)
    dim = Table({**dimc.columns,
                 "cat": jnp.arange(512, dtype=jnp.int32) % 64})
    ref = reference_query_numpy(fact, dim)
    fd = distribute(fact, range(4), "A")
    dd = distribute(dim, range(2), "B")

    # measure the unconstrained high-water mark first
    got, rt = execute_query_runtime(fd, dd, QueryStrategy("static_merge"))
    np.testing.assert_allclose(got, ref, atol=1e-3)
    peak = rt.store.peak_bytes["query"]

    gc = GlobalController({n: 8 for n in range(4)})
    rt2 = Runtime(gc)
    rt2.store.set_quota("query", peak)
    got2, _ = execute_query_runtime(fd, dd, QueryStrategy("static_merge"),
                                    runtime=rt2)
    np.testing.assert_allclose(got2, ref, atol=1e-3)
    assert rt2.store.peak_bytes["query"] <= peak
    # sealing kept consumed shuffle state around until pressure reclaimed it
    assert rt2.store.evictions


def test_disagg_transfer_charged_only_after_quota_admission():
    """Regression: the emulated disaggregated-transfer sleep is paid only
    AFTER quota admission succeeds. A fail-fast oversized write must return
    immediately (no transfer for bytes that were never admitted), and an
    evict-then-retry admission pays the transfer exactly once — the same
    charge as a first-try admission of the same blob."""
    bw = 1000.0                      # bytes/s: a 200-byte blob "moves" in .2s
    store = ShuffleStore(net_bw=bw, disaggregated=True,
                         quotas={"a": 250})
    # fail-fast: delta > quota raises before any transfer is charged
    t0 = time.perf_counter()
    with pytest.raises(QuotaExceededError):
        store.put("a", "s0", 0, FakeTable(400, 4), node=0, writer="w")
    assert time.perf_counter() - t0 < 0.15
    # first-try admission: exactly one transfer
    t0 = time.perf_counter()
    store.put("a", "s0", 0, FakeTable(200, 2), node=0, writer="w")
    first = time.perf_counter() - t0
    store.seal("a", "s0")
    # evict-then-retry admission: evicts the sealed stage, then pays the
    # transfer once — accounting identical to the first-try path
    t0 = time.perf_counter()
    store.put("a", "s1", 0, FakeTable(200, 2), node=0, writer="w")
    second = time.perf_counter() - t0
    assert store.evictions == [("a", "s0", 200)]
    assert 0.2 <= first < 0.38 and 0.2 <= second < 0.38


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_hypothesis_present_marker():
    """Explicit marker so CI logs show whether the property suites really
    executed (they silently skip on bare environments)."""
    assert HAVE_HYPOTHESIS
