"""Control-plane behaviour tests: decision workflows + controllers."""

import threading

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    ConflictError,
    DataDist,
    Decision,
    DecisionContext,
    DecisionNode,
    DecisionWorkflow,
    GlobalController,
    PrivateController,
    Schedule,
    default_node,
)


def make_gc(nodes=4, slots=8):
    return GlobalController({n: slots for n in range(nodes)})


# -- Schedule placement ----------------------------------------------------------


def test_round_robin_spreads():
    sch = Schedule("round-robin", (0, 1, 2))
    assert sch.place(6) == (0, 1, 2, 0, 1, 2)


def test_packing_fills_nodes_first():
    sch = Schedule("packing", (0, 1, 2), slots_per_node=2)
    assert sch.place(5) == (0, 0, 1, 1, 2)


def test_packing_overflow_stays_on_last_node():
    sch = Schedule("packing", (0,), slots_per_node=2)
    assert sch.place(4) == (0, 0, 0, 0)


# -- GlobalController ---------------------------------------------------------


def test_commit_and_release_restores_slots():
    gc = make_gc()
    claim = gc.commit("app", 0, [0, 0, 1])
    assert gc.used == {0: 2, 1: 1, 2: 0, 3: 0}
    gc.release(claim)
    assert sum(gc.used.values()) == 0


def test_oversubscription_rejected():
    gc = make_gc(nodes=1, slots=2)
    gc.commit("a", 5, [0, 0])
    with pytest.raises(ConflictError):
        gc.commit("b", 5, [0])          # equal priority: no preemption


def test_priority_preemption_evicts_low():
    gc = make_gc(nodes=1, slots=2)
    low = gc.commit("bg", 0, [0, 0])
    hi = gc.commit("query", 10, [0, 0])
    assert hi.claim_id in gc.claims
    assert low.claim_id not in gc.claims
    assert len(gc.preemptions) == 1
    assert gc.preemptions[0].victim.app == "bg"


def test_preemption_does_not_evict_higher():
    gc = make_gc(nodes=1, slots=2)
    gc.commit("query", 10, [0, 0])
    with pytest.raises(ConflictError):
        gc.commit("bg", 0, [0])


def test_try_commit_returns_none_instead_of_raising():
    gc = make_gc(nodes=1, slots=1)
    a = gc.try_commit("a", 5, [0])
    assert a is not None
    assert gc.try_commit("b", 5, [0]) is None     # equal priority: no slot
    assert gc.finish(a) is True
    assert gc.finish(a) is False                  # already released


def test_finish_reports_mid_flight_preemption():
    gc = make_gc(nodes=1, slots=1)
    low = gc.commit("bg", 0, [0])
    hi = gc.commit("query", 10, [0])              # evicts the running claim
    assert gc.is_active(hi) and not gc.is_active(low)
    assert gc.finish(low) is False                # invoker must retry
    assert gc.finish(hi) is True
    assert sum(gc.used.values()) == 0


def test_node_status_view_is_consistent():
    gc = make_gc()
    gc.commit("a", 0, [1, 1, 2])
    status = gc.node_status()
    assert status.free_slots[1] == 6
    assert status.free() == 4 * 8 - 3


def test_concurrent_commits_never_oversubscribe():
    gc = make_gc(nodes=2, slots=16)
    errors = []

    def worker(i):
        try:
            for _ in range(50):
                c = gc.commit(f"app{i}", 0, [i % 2])
                gc.release(c)
        except ConflictError:
            pass
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert all(0 <= gc.used[n] <= gc.total[n] for n in gc.total)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(1, 6)),
                min_size=1, max_size=40))
def test_slot_accounting_invariant(ops):
    """Property: used slots never exceed totals nor go negative."""
    gc = make_gc(nodes=4, slots=4)
    live = []
    for node, count in ops:
        try:
            live.append(gc.commit("app", 0, [node] * count))
        except ConflictError:
            if live:
                gc.release(live.pop())
        assert all(0 <= gc.used[n] <= gc.total[n] for n in gc.total)
    for c in live:
        gc.release(c)
    assert sum(gc.used.values()) == 0


# -- Decision workflows ----------------------------------------------------------


def test_default_node_uses_all_free_slots():
    gc = make_gc(nodes=2, slots=4)
    node = default_node("fallback")
    d = node.decide(DecisionContext(node_status=gc.node_status()))
    assert d.scale == 8
    assert d.schedule.policy == "round-robin"


def test_workflow_runs_in_order_with_feedback():
    wf = DecisionWorkflow("q")
    seen = []

    def mk(name):
        def fn(ctx):
            seen.append((name, dict(ctx.profile)))
            return Decision(name, 1, Schedule("round-robin", (0,)))
        return DecisionNode(name, fn)

    wf.add(mk("a")).add(mk("b"), depends_on=["a"])

    def executor(name, decision, ctx):
        return {"latency": 1.0}

    decisions = wf.run(DecisionContext(), executor)
    assert list(decisions) == ["a", "b"]
    # stage b observed stage a's feedback (paper Fig. 5 step 4)
    assert "a.latency" in seen[1][1]


def test_workflow_rejects_unknown_dependency():
    wf = DecisionWorkflow("q")
    with pytest.raises(ValueError):
        wf.add(default_node("x"), depends_on=["nope"])


def test_decision_node_fallback_on_error():
    def broken(ctx):
        raise RuntimeError("custom logic bug")

    node = DecisionNode(
        "j", broken,
        fallback=lambda ctx: Decision("default", 1,
                                      Schedule("round-robin", (0,))))
    d = node.decide(DecisionContext())
    assert d.func == "default"


def test_private_controller_enacts_decision():
    gc = make_gc(nodes=2, slots=2)
    pc = PrivateController("q", gc, priority=5)
    pc.observe_data(DataDist("A", {0: 100, 1: 50}))
    claim = pc.enact(Decision("f", 3, Schedule("round-robin", (0, 1))))
    assert sum(claim.slots_per_node().values()) == 3
    pc.release_all()
    assert sum(gc.used.values()) == 0
