"""Tiered shuffle storage: backends, the tiering decision node, spill /
promote through a full query, lineage recovery with spilled inputs, the
cold-data (object-store-seeded) scenario, and cross-plane decision parity.

The contract under test: byte-identical query results on every primary
backend (memory / disk / emulated object store), a seventh ``tiering``
decision node that chooses spill-vs-evict per reclaimable stage from
plan-derived inputs only (so runtime and simulator bind identical
sequences), demotion that keeps sealed stages readable instead of
tombstoning them, transparent promotion on read, and dollar-cost billing
for the priced object tier.
"""

import numpy as np
import pytest

from repro.analytics import (
    QueryStrategy,
    Table,
    execute_query_runtime,
    synth_query_tables,
)
from repro.analytics.planner import (
    build_query_workflow,
    ephemeral_stage_profile,
    plan_query_with_workflow,
)
from repro.analytics.simulator import ClusterSim
from repro.core.controllers import GlobalController, PrivateController
from repro.core.decisions import (
    DecisionContext,
    NodeStatus,
    tiering_choice,
    tiering_node,
)
from repro.runtime import (
    DiskBackend,
    FaultInjector,
    FaultPlan,
    MemoryBackend,
    ObjectStoreBackend,
    Runtime,
    ShuffleStore,
    StageLossFault,
    make_backend,
)
from repro.runtime.storage import deserialize_table, serialize_table


class PickleTable:
    """Module-level duck-typed table so the pickle fallback roundtrips."""

    def __init__(self, nbytes: int, rows: int):
        self.nbytes = nbytes
        self.num_rows = rows

    def concat(self, other: "PickleTable") -> "PickleTable":
        return PickleTable(self.nbytes + other.nbytes,
                           self.num_rows + other.num_rows)


@pytest.fixture(scope="module")
def tables():
    return synth_query_tables(4096, 512, seed=1)


def _cheap_object_backend(**over):
    kw = dict(latency_s=0.0, bw=None, cost_per_request=0.0, cost_per_gb=0.0)
    kw.update(over)
    return ObjectStoreBackend(**kw)


# -- backend unit tests ------------------------------------------------------------


@pytest.mark.parametrize("factory", [MemoryBackend, DiskBackend,
                                     _cheap_object_backend])
def test_backend_bytes_api_roundtrip(factory):
    b = factory()
    try:
        b.put("a/s/0/w", b"\x00\x01payload")
        b.put("a/s/1/w", b"other")
        assert b.get("a/s/0/w") == b"\x00\x01payload"
        assert b.list("a/s/") == ["a/s/0/w", "a/s/1/w"]
        assert b.list("a/s/1") == ["a/s/1/w"]
        b.delete("a/s/0/w")
        b.delete("a/s/0/w")          # idempotent
        with pytest.raises(KeyError):
            b.get("a/s/0/w")
        assert b.list() == ["a/s/1/w"]
    finally:
        b.close()


def test_disk_backend_owns_and_removes_its_tempdir():
    b = DiskBackend()
    root = b.root
    b.put("k", b"x")
    assert root.exists() and any(root.iterdir())
    b.close()
    assert not root.exists()


def test_disk_backend_leaves_external_root_alone(tmp_path):
    b = DiskBackend(root=tmp_path)
    b.put("k", b"x")
    b.close()
    assert tmp_path.exists()         # caller-owned directory survives close


def test_serialize_roundtrips_table_and_slice():
    t = Table({"k": np.arange(8, dtype=np.int32),
               "v": np.linspace(0.0, 1.0, 8, dtype=np.float32)})
    got = deserialize_table(serialize_table(t))
    for col in ("k", "v"):
        np.testing.assert_array_equal(np.asarray(got[col]),
                                      np.asarray(t[col]))
    # a lazy slice view materializes into the payload
    got_slice = deserialize_table(serialize_table(t.slice(2, 6)))
    np.testing.assert_array_equal(np.asarray(got_slice["k"]),
                                  np.arange(2, 6, dtype=np.int32))


def test_serialize_pickle_fallback_for_duck_typed_tables():
    got = deserialize_table(serialize_table(PickleTable(64, 3)))
    assert (got.nbytes, got.num_rows) == (64, 3)
    with pytest.raises(ValueError, match="magic"):
        deserialize_table(b"XXXXjunk")


def test_make_backend_resolves_names_and_instances():
    assert make_backend("memory").tier == "memory"
    assert make_backend("disk").tier == "disk"
    inst = _cheap_object_backend()
    assert make_backend(inst) is inst
    with pytest.raises(ValueError, match="unknown storage backend"):
        make_backend("tape")


def test_object_store_pricing_model():
    b = ObjectStoreBackend(latency_s=0.01, bw=100e6,
                           cost_per_request=4e-7, cost_per_gb=0.01)
    assert b.io_seconds(100e6) == pytest.approx(0.01 + 1.0)
    assert b.request_cost(1e9) == pytest.approx(4e-7 + 0.01)
    spec = b.spec()
    assert spec["tier"] == "object" and spec["order"] == 2
    assert spec["cost_per_gb"] == 0.01


# -- the tiering decision rule and node --------------------------------------------


def test_tiering_choice_spills_to_disk_when_reread_likely():
    disk = DiskBackend().spec()
    # 100 KB stage, deep lineage, likely re-read: disk write+read is far
    # cheaper than replaying the producer chain
    func, tier = tiering_choice(100_000, reread_p=0.5,
                                recompute_s=0.1, tiers={"disk": disk})
    assert (func, tier) == ("spill", "disk")


def test_tiering_choice_evicts_when_recompute_is_free():
    disk = DiskBackend().spec()
    func, tier = tiering_choice(100_000, reread_p=0.0,
                                recompute_s=0.0, tiers={"disk": disk})
    assert (func, tier) == ("evict", None)


def test_tiering_choice_dollars_penalize_the_object_tier():
    # per-request dollars monetized into seconds make the priced object
    # tier lose to both eviction-with-cheap-recompute and local disk
    obj = ObjectStoreBackend().spec()
    disk = DiskBackend().spec()
    func, tier = tiering_choice(10_000, reread_p=0.2, recompute_s=1e-4,
                                tiers={"object": obj})
    assert func == "evict"
    func, tier = tiering_choice(10_000, reread_p=0.2, recompute_s=1.0,
                                tiers={"object": obj, "disk": disk})
    assert (func, tier) == ("spill", "disk")


def _bind_tiering(profile):
    node = tiering_node()
    ctx = DecisionContext(profile=profile,
                          node_status=NodeStatus(total_slots={0: 8, 1: 8}))
    return node.fn(ctx)


def test_tiering_node_keeps_without_quota_or_tiers():
    stages = (("joined", 100_000, 3, 1),)
    tiers = {"disk": DiskBackend().spec()}
    for profile in (
            {"tiering.stages": stages, "tiering.quota": None,
             "tiering.tiers": tiers},
            {"tiering.stages": stages, "tiering.quota": 1 << 20,
             "tiering.tiers": {}},
            {"tiering.stages": (), "tiering.quota": 1 << 20,
             "tiering.tiers": tiers}):
        d = _bind_tiering(profile)
        assert d.func == "keep" and d.extra("plan", None) == ()


def test_tiering_node_plans_per_stage():
    d = _bind_tiering({
        "tiering.stages": (("joined", 1 << 20, 3, 1),
                           ("partials", 256, 4, 0)),
        "tiering.quota": 1 << 20,
        "tiering.tiers": {"disk": DiskBackend().spec()}})
    plan = dict(d.extra("plan", ()))
    # the megabyte-deep stage spills; the tiny partials are cheaper to
    # recompute than to write out
    assert plan["joined"] == "disk"
    assert plan["partials"] == "evict"
    assert d.func == "spill" and d.scale == 1


# -- oracle equality on every primary backend --------------------------------------


def _primary(name: str):
    return _cheap_object_backend() if name == "object" else name


@pytest.mark.parametrize("backend", ["disk", "object"])
def test_query_oracle_equal_on_cold_primary_backend(tables, backend):
    fd, dd, ref = tables
    gc = GlobalController({n: 8 for n in range(4)})
    rt = Runtime(gc, storage=_primary(backend))
    try:
        got, _ = execute_query_runtime(fd, dd, QueryStrategy("static_merge"),
                                       runtime=rt)
        np.testing.assert_allclose(got, ref, atol=1e-3)
        assert sum(gc.used.values()) == 0
    finally:
        rt.store.close()


@pytest.mark.parametrize("backend", ["disk", "object"])
def test_query_recovers_from_stage_loss_on_cold_primary(tables, backend):
    fd, dd, ref = tables
    gc = GlobalController({n: 8 for n in range(4)})
    rt = Runtime(gc, storage=_primary(backend))
    try:
        FaultInjector(FaultPlan(losses=[
            StageLossFault("joined", partitions=(0,), on_read=1)
        ])).install(rt)
        got, _ = execute_query_runtime(fd, dd, QueryStrategy("static_merge"),
                                       runtime=rt)
        np.testing.assert_allclose(got, ref, atol=1e-3)
        assert len(rt.recoveries) == 1
        assert rt.recoveries[0].lost_stage == "joined"
    finally:
        rt.store.close()


# -- spill integration: quota + cold tiers through a full query --------------------


def _unconstrained_peak(tables, strategy="static_merge") -> int:
    fd, dd, ref = tables
    got, rt = execute_query_runtime(fd, dd, QueryStrategy(strategy))
    np.testing.assert_allclose(got, ref, atol=1e-3)
    return rt.store.peak_bytes["query"]


def test_quota_with_spill_backends_demotes_instead_of_tombstoning(tables):
    fd, dd, ref = tables
    quota = _unconstrained_peak(tables)
    gc = GlobalController({n: 8 for n in range(4)})
    rt = Runtime(gc, spill_backends=[DiskBackend()])
    rt.store.set_quota("query", quota)
    wf = build_query_workflow(QueryStrategy("static_merge"))
    try:
        got, _ = execute_query_runtime(fd, dd, QueryStrategy("static_merge"),
                                       runtime=rt, workflow=wf)
        np.testing.assert_allclose(got, ref, atol=1e-3)
        tiering = dict(wf.last_run.sequence)["tiering"]
        assert tiering.func == "spill"
        plan = dict(tiering.extra("plan", ()))
        assert "disk" in plan.values()
        # reclaimed stages with a spill policy were demoted, not tombstoned
        assert rt.store.demotions
        assert {s for _, s, _, _ in rt.store.demotions} <= set(plan)
        assert rt.store.peak_bytes["query"] <= quota
    finally:
        rt.store.close()


def test_lost_stage_recovers_through_spilled_inputs(tables):
    """PR-4 fault plans still hold with tiering: losing the partials after
    the join output was reclaimed-and-spilled recovers via lineage — the
    recompute reads the demoted 'joined' through the disk backend instead
    of replaying the whole producer chain."""
    fd, dd, ref = tables
    quota = _unconstrained_peak(tables)
    gc = GlobalController({n: 8 for n in range(4)})
    rt = Runtime(gc, spill_backends=[DiskBackend()])
    rt.store.set_quota("query", quota)
    try:
        FaultInjector(FaultPlan(losses=[
            StageLossFault("partials", on_read=1)
        ])).install(rt)
        got, _ = execute_query_runtime(fd, dd, QueryStrategy("static_merge"),
                                       runtime=rt)
        np.testing.assert_allclose(got, ref, atol=1e-3)
        assert rt.recoveries and \
            rt.recoveries[0].lost_stage == "partials"
        assert rt.store.demotions        # the inputs it replayed were spilled
    finally:
        rt.store.close()


def test_object_spill_bills_storage_cost():
    store = ShuffleStore(spill_backends=[
        ObjectStoreBackend(latency_s=0.0, bw=None,
                           cost_per_request=1e-3, cost_per_gb=0.0)])
    t = Table({"k": np.arange(4, dtype=np.int32)})
    store.put("app", "s", 0, t, node=0, writer="w")
    assert store.storage_cost.get("app", 0.0) == 0.0
    store.demote_stage("app", "s", "object")
    assert store.storage_cost["app"] == pytest.approx(1e-3)    # the PUT
    got = store.get("app", "s", 0, node=0)
    np.testing.assert_array_equal(np.asarray(got["k"]), np.arange(4))
    # the GET billed too, then promotion made the blob hot again for free
    assert store.storage_cost["app"] == pytest.approx(2e-3)
    assert store.promotions and store.app_bytes["app"] == t.nbytes
    store.get("app", "s", 0, node=0)
    assert store.storage_cost["app"] == pytest.approx(2e-3)


# -- the cold-data scenario: object-store-seeded inputs ----------------------------


def test_cold_seeded_inputs_first_touch_then_warm_requery(tables):
    fd, dd, ref = tables
    gc = GlobalController({n: 8 for n in range(4)})
    rt = Runtime(gc, spill_backends=[
        ObjectStoreBackend(latency_s=0.0, bw=None)])   # priced, not slowed
    try:
        got, _ = execute_query_runtime(fd, dd, QueryStrategy("static_merge"),
                                       runtime=rt, seed_tier="object")
        np.testing.assert_allclose(got, ref, atol=1e-3)
        # first touch read through the object store: dollars billed, and
        # the scanned inputs promoted into memory
        first_cost = rt.store.storage_cost["query"]
        assert first_cost > 0
        assert any(s == "input/fact" for _, s, _, _, _ in
                   rt.store.promotions)
        # warm re-query: inputs are reused in place (no re-seed), reads are
        # hot, and not one more object-store dollar is billed
        got2, _ = execute_query_runtime(fd, dd,
                                        QueryStrategy("static_merge"),
                                        runtime=rt, reuse_inputs=True)
        np.testing.assert_allclose(got2, ref, atol=1e-3)
        assert rt.store.storage_cost["query"] == first_cost
    finally:
        rt.store.close()


# -- cross-plane parity: seven nodes, tiers + quota engaged ------------------------


def test_tiering_decision_parity_across_planes(tables):
    fd, dd, ref = tables
    quota = _unconstrained_peak(tables, strategy="dynamic")
    wf = build_query_workflow(QueryStrategy("dynamic"))

    gc_rt = GlobalController({n: 8 for n in range(4)})
    rt = Runtime(gc_rt, spill_backends=[DiskBackend(),
                                        _cheap_object_backend()])
    rt.store.set_quota("query", quota)
    try:
        got, _ = execute_query_runtime(fd, dd, QueryStrategy("dynamic"),
                                       runtime=rt, workflow=wf)
        np.testing.assert_allclose(got, ref, atol=1e-3)
        spec = rt.store.storage_spec()
        seq_rt = [(s, d.func, d.scale, d.extra("plan", None))
                  for s, d in wf.last_run.sequence]
    finally:
        rt.store.close()

    gc_sim = GlobalController({n: 8 for n in range(4)})
    sim = ClusterSim(gc_sim, storage_spec=spec,
                     store_quotas={"query": quota})
    pc = PrivateController("query", gc_sim, priority=10)
    plan_query_with_workflow(sim, pc, fd, dd, QueryStrategy("dynamic"),
                             workflow=wf)
    sim.run()
    seq_sim = [(s, d.func, d.scale, d.extra("plan", None))
               for s, d in wf.last_run.sequence]

    assert [s for s, *_ in seq_rt] == ["scan", "join", "exchange",
                                       "skew", "aggregate", "pipeline",
                                       "elastic", "tiering"]
    assert seq_rt == seq_sim           # per-stage spill plans included
    assert dict((s, f) for s, f, _, _ in seq_rt)["tiering"] == "spill"
