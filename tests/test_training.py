"""Training substrate: optimizer math, losses, microbatch equivalence,
end-to-end loss descent."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.config import (
    OptimizerConfig,
    ParallelConfig,
    ShapeConfig,
)
from repro.data import SyntheticSource
from repro.models import init_lm
from repro.models.lm import forward_hidden
from repro.training import (
    chunked_cross_entropy,
    init_opt_state,
    lr_schedule,
    make_train_step,
)
from repro.training.optimizer import apply_updates, global_norm

SHAPE = ShapeConfig("t", 32, 4, "train")


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = init_opt_state(params)
    cfg = OptimizerConfig(lr=0.1, warmup_steps=0, weight_decay=0.0)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = apply_updates(params, grads, state, cfg,
                                         total_steps=10 ** 6)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros((4,))}
    state = init_opt_state(params)
    cfg = OptimizerConfig(lr=1.0, warmup_steps=0, grad_clip=1.0,
                          weight_decay=0.0)
    huge = {"w": jnp.full((4,), 1e6)}
    _, _, metrics = apply_updates(params, huge, state, cfg)
    assert float(metrics["grad_norm"]) > 1e5   # reported pre-clip


def test_lr_schedule_warmup_and_decay():
    cfg = OptimizerConfig(lr=1e-3, warmup_steps=10)
    lrs = [float(lr_schedule(cfg, jnp.asarray(s), total_steps=100))
           for s in (0, 5, 10, 50, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(5e-4, rel=1e-3)
    assert lrs[2] == pytest.approx(1e-3, rel=1e-2)
    assert lrs[3] < lrs[2]
    assert lrs[4] == pytest.approx(1e-4, rel=1e-2)   # floor = 0.1 * lr


def test_global_norm():
    tree = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(tree)) == pytest.approx(5.0)


def test_chunked_ce_matches_dense():
    cfg = get_config("llama3.2-3b", smoke=True)
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    b, s = 2, 24
    h = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model),
                          jnp.float32)
    labels = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0,
                                cfg.vocab_size, jnp.int32)
    labels = labels.at[0, :4].set(-1)    # masked positions
    loss_c, count = chunked_cross_entropy(params["embed"], h, labels, cfg,
                                          chunk=8)
    # dense reference (llama3.2 ties embeddings: unembed = table.T)
    table = params["embed"].get("unembed",
                                params["embed"]["table"].T)
    logits = (h @ table).astype(jnp.float32)
    vpad = table.shape[-1]
    logits = jnp.where(jnp.arange(vpad) < cfg.vocab_size, logits, -1e9)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None],
                             axis=-1)[..., 0]
    mask = (labels >= 0)
    ref = jnp.sum((lse - ll) * mask) / jnp.sum(mask)
    assert float(count) == int(mask.sum())
    np.testing.assert_allclose(float(loss_c), float(ref), rtol=1e-5)


def test_chunked_ce_handles_nondivisible_seq():
    cfg = get_config("llama3.2-3b", smoke=True)
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    h = jax.random.normal(jax.random.PRNGKey(1), (1, 30, cfg.d_model))
    labels = jnp.zeros((1, 30), jnp.int32)
    loss, count = chunked_cross_entropy(params["embed"], h, labels, cfg,
                                        chunk=8)
    assert float(count) == 30 and np.isfinite(float(loss))


def test_microbatch_accumulation_equivalent():
    """mb=1 and mb=2 must produce (nearly) the same updated params."""
    cfg = get_config("llama3.2-3b", smoke=True)
    opt_cfg = OptimizerConfig(lr=1e-3, warmup_steps=0)
    source = SyntheticSource(cfg, SHAPE, seed=3)
    batch = {k: jnp.asarray(v) for k, v in source.batch(0).items()}

    results = []
    for mb in (1, 2):
        params, _ = init_lm(cfg, jax.random.PRNGKey(0))
        state = {"params": params, "opt": init_opt_state(params)}
        step = make_train_step(cfg, SHAPE, opt_cfg,
                               ParallelConfig(microbatches=mb, remat="none"),
                               q_chunk=16, ssm_chunk=8)
        new_state, metrics = jax.jit(step)(state, batch)
        results.append((new_state["params"], float(metrics["loss"])))

    assert results[0][1] == pytest.approx(results[1][1], rel=1e-3)
    flat0 = jax.tree.leaves(results[0][0])
    flat1 = jax.tree.leaves(results[1][0])
    for a, b in zip(flat0, flat1):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=5e-3)


def test_train_step_reduces_loss_on_fixed_batch():
    """Memorization: repeated steps on one batch must descend."""
    cfg = get_config("qwen1.5-4b", smoke=True)
    opt_cfg = OptimizerConfig(lr=3e-3, warmup_steps=0)
    source = SyntheticSource(cfg, SHAPE, seed=1)
    batch = {k: jnp.asarray(v) for k, v in source.batch(0).items()}
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    state = {"params": params, "opt": init_opt_state(params)}
    step = jax.jit(make_train_step(cfg, SHAPE, opt_cfg,
                                   ParallelConfig(remat="none"),
                                   q_chunk=16, ssm_chunk=8))
    losses = []
    for _ in range(8):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses


@pytest.mark.parametrize("arch", ["granite-moe-1b-a400m", "jamba-v0.1-52b",
                                  "xlstm-1.3b", "musicgen-medium",
                                  "internvl2-1b"])
def test_train_step_runs_all_families(arch):
    cfg = get_config(arch, smoke=True)
    shape = ShapeConfig("t", 32, 2, "train")
    source = SyntheticSource(cfg, shape, seed=0)
    batch = {k: jnp.asarray(v) for k, v in source.batch(0).items()}
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    state = {"params": params, "opt": init_opt_state(params)}
    step = jax.jit(make_train_step(cfg, shape, OptimizerConfig(),
                                   ParallelConfig(remat="none"),
                                   q_chunk=16, ssm_chunk=8))
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
