"""The process-backed worker plane (``repro.runtime.workers``).

Covers the pool's cold-start economics (warm LIFO reuse, idle reap,
resize), oracle equivalence of a full query on the ``process`` backend,
SIGKILL chaos — killed workers never leak controller slots, never leave
partial store writes, and heal through the standard crash-retry/lineage
machinery — and the elastic decision node's behavior on both data planes
(the runtime pool and the simulator's cold-start twin).

Worker subprocesses use the "spawn" start method and pay a real jax import
per cold start (~1s locally), so pools here stay at 1-2 workers.
"""

import time

import numpy as np
import pytest

from tests._hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.analytics import QueryStrategy, execute_query_runtime
from repro.analytics.simulator import ClusterSim, SimTask
from repro.core.controllers import GlobalController
from repro.core.decisions import worker_pool_target
from repro.runtime import (
    FaultInjector,
    FaultPlan,
    QueryJob,
    QueryScheduler,
    Runtime,
    WorkerKillFault,
    WorkerPool,
)
from tests.test_runtime import make_dist_tables


# -- pool economics (no query machinery involved) ---------------------------------


def test_pool_warm_reuse_and_function_seconds():
    pool = WorkerPool(max_workers=1)
    try:
        w, cold = pool.lease()
        assert cold and w.pid is not None
        pid = w.pid
        pool.release(w, busy_s=0.5)
        w2, cold2 = pool.lease()
        # LIFO warm reuse: same process, no second provision
        assert not cold2 and w2.pid == pid
        pool.release(w2, busy_s=0.25)
        assert pool.cold_starts == 1 and pool.warm_hits == 1
        # the bill: busy function-seconds plus the measured provision charge
        assert pool.cost_function_seconds() >= 0.75 + pool.provision_seconds \
            - 1e-6
        assert pool.provision_seconds > 0
    finally:
        pool.shutdown()


def test_pool_provision_floor_is_modeled_cold_start():
    t0 = time.perf_counter()
    pool = WorkerPool(max_workers=1, provision_s=3.0)
    try:
        _, cold = pool.lease()
        assert cold
        # a local spawn beats 3s; the model sleeps the remainder and bills
        # the floor
        assert time.perf_counter() - t0 >= 3.0
        assert pool.provision_seconds >= 3.0
    finally:
        pool.shutdown()


def test_pool_idle_reap_and_resize():
    pool = WorkerPool(max_workers=2, idle_reap_s=0.2)
    try:
        w, _ = pool.lease()
        first_pid = w.pid
        pool.release(w, busy_s=0.0)
        assert pool.size() == 1
        time.sleep(0.35)
        # lazy reap at the next interaction: the expired worker is retired
        # and the lease cold-starts a replacement
        w2, cold = pool.lease()
        assert cold and w2.pid != first_pid
        assert pool.reaped == 1 and pool.cold_starts == 2
        pool.release(w2, busy_s=0.0)
        # resize pre-warms to target, then shrinks back down
        assert pool.resize(2) == 2
        assert pool.cold_starts == 3
        assert pool.resize(1) == 1
        # grow is clamped at max_workers
        assert pool.resize(99) == 2
    finally:
        pool.shutdown()


def test_worker_pool_target_rule():
    # ceil(fanout / tasks_per_worker), clamped to [min_workers, max_workers]
    assert worker_pool_target(0, 5) == 1
    assert worker_pool_target(4, 0) == 1
    assert worker_pool_target(17, 0) == 5
    assert worker_pool_target(1024, 0) == 16
    assert worker_pool_target(1024, 0, max_workers=4) == 4


# -- full query on the process backend --------------------------------------------


def test_process_backend_query_matches_oracle_with_elastic_decision():
    fd, dd, ref = make_dist_tables()
    gc = GlobalController({n: 8 for n in range(4)})
    rt = Runtime(gc, invoker="process", max_workers=2)
    try:
        sched = QueryScheduler(rt, policy="fifo")
        sched.submit(QueryJob("q1", fd, dd, "static_merge"))
        res = sched.run()["q1"]
        assert res.ok, res.error
        np.testing.assert_allclose(res.sums, ref, atol=1e-3)
        # the sixth decision node bound on the runtime plane, last
        assert [n for n, _ in res.decisions] == \
            ["scan", "join", "exchange", "skew", "aggregate", "pipeline",
             "elastic", "tiering"]
        elastic = dict(res.decisions)["elastic"]
        assert elastic.func in ("grow", "shrink", "hold")
        assert elastic.scale >= 1
        # no leaked claims, and the pool actually reused warm workers
        assert sum(gc.used.values()) == 0
        stats = rt.invoker.pool.stats()
        assert stats["warm_hits"] > 0
        assert stats["cost_function_seconds"] > 0
    finally:
        rt.invoker.shutdown()


# -- SIGKILL chaos ----------------------------------------------------------------


def _run_killed_query(kills, seed=7):
    fd, dd, ref = make_dist_tables(seed=seed)
    gc = GlobalController({n: 8 for n in range(4)})
    rt = Runtime(gc, invoker="process", max_workers=2)
    FaultInjector(FaultPlan(worker_kills=list(kills))).install(rt)
    try:
        got, _ = execute_query_runtime(fd, dd, QueryStrategy("static_merge"),
                                       runtime=rt)
        np.testing.assert_allclose(got, ref, atol=1e-3)
        return rt, gc
    finally:
        rt.invoker.shutdown()


@pytest.mark.parametrize("when", ["body", "late"])
def test_worker_kill_heals_with_clean_slots(when):
    """A SIGKILLed worker surfaces as a crashed attempt, releases its slot
    claim, and the retry completes on a fresh worker. ``when="late"`` kills
    after the body ran — every write was still buffered worker-side, so the
    store sees none of them (the no-partial-writes invariant)."""
    rt, gc = _run_killed_query(
        [WorkerKillFault("scan_fact", index=1, when=when)])
    recs = [(r.status, r.attempt) for r in rt.metrics.records
            if r.name == "query/scan_fact/1"]
    assert ("crashed", 0) in recs and ("ok", 1) in recs
    assert sum(gc.used.values()) == 0
    assert ("worker-kill", "query/scan_fact/1") in rt.invoker.injector.injected
    # the healed store holds exactly one live write per scan partition
    assert sorted(rt.store.partitions("query", "scan_fact")) == [0, 1, 2, 3]


def test_worker_kill_mid_join_recovers_and_replaces_worker():
    """Killing a join worker mid-read exercises the host-side RPC path: the
    pipe EOF surfaces as WorkerKilledError, the poisoned worker is retired
    (never reused), and the retry runs on a replacement process."""
    rt, gc = _run_killed_query(
        [WorkerKillFault("join", index=0, when="body")], seed=3)
    recs = [(r.status, r.attempt) for r in rt.metrics.records
            if r.name == "query/join/0"]
    assert ("crashed", 0) in recs and ("ok", 1) in recs
    assert sum(gc.used.values()) == 0
    # a killed worker is replaced, not reused: at least one extra cold start
    assert rt.invoker.pool.cold_starts >= 2


if HAVE_HYPOTHESIS:
    _kill_strategy = st.lists(
        st.tuples(st.sampled_from(["scan_fact", "join", "partial_agg"]),
                  st.integers(0, 1), st.sampled_from(["body", "late"])),
        min_size=1, max_size=2, unique_by=lambda k: (k[0], k[1]))
else:                                    # pragma: no cover - shim path
    _kill_strategy = None


@settings(max_examples=3, deadline=None)
@given(kills=_kill_strategy)
def test_chaos_worker_kill_schedules_never_leak(kills):
    """Property: any small schedule of worker kills still completes with
    the oracle result, zero leaked controller slots, and one crashed record
    per fired kill."""
    plan = [WorkerKillFault(stage, index=idx, when=when)
            for stage, idx, when in kills]
    rt, gc = _run_killed_query(plan, seed=13)
    assert sum(gc.used.values()) == 0
    crashed = [r for r in rt.metrics.records if r.status == "crashed"]
    assert len(crashed) == len(rt.invoker.injector.injected)
    assert all(kind == "worker-kill"
               for kind, _ in rt.invoker.injector.injected)


# -- the simulator's cold-start twin ----------------------------------------------


def _sim_wave(provision_s, warm_pool, n=4, slots=4):
    gc = GlobalController({0: slots})
    sim = ClusterSim(gc, provision_s=provision_s, warm_pool=warm_pool)
    for i in range(n):
        sim.submit(SimTask(f"a/map1/{i}", "a", 1.0, node=0))
    return sim, sim.run()


def test_sim_cold_starts_vs_warm_pool():
    cold_sim, cold_out = _sim_wave(provision_s=2.0, warm_pool=0)
    warm_sim, warm_out = _sim_wave(provision_s=2.0, warm_pool=4)
    assert cold_sim.cold_starts == 4 and cold_sim.warm_hits == 0
    assert warm_sim.warm_hits == 4 and warm_sim.cold_starts == 0
    # provisioning sits on the critical path and on the bill
    assert warm_out["completion"]["a"] + 2.0 <= cold_out["completion"]["a"]
    assert warm_out["cost_function_seconds"]["a"] + 8.0 <= \
        cold_out["cost_function_seconds"]["a"] + 1e-9


def test_sim_warm_reuse_across_waves_and_prewarm_billing():
    # 1 slot serializes 3 tasks: first cold-starts, the rest lease warm
    sim, _ = _sim_wave(provision_s=2.0, warm_pool=0, n=3, slots=1)
    assert sim.cold_starts == 1 and sim.warm_hits == 2
    assert sim.pool == 1
    # prewarm (the elastic "grow" path) bills provision up front
    gc = GlobalController({0: 4})
    sim2 = ClusterSim(gc, provision_s=2.0)
    sim2.prewarm(3, app="a")
    assert sim2.pool == 3 and sim2.cold_starts == 3
    assert sim2.fn_seconds["a"] == pytest.approx(6.0)
    for i in range(3):
        sim2.submit(SimTask(f"a/map1/{i}", "a", 1.0, node=0))
    out = sim2.run()
    assert sim2.warm_hits == 3           # the fan-out leased warm
    assert out["completion"]["a"] == pytest.approx(1.0)


def test_sim_idle_reap_retires_warm_workers():
    gc = GlobalController({0: 1})
    sim = ClusterSim(gc, provision_s=2.0, idle_reap_s=0.5)
    sim.prewarm(2, app="a")
    assert sim.pool == 2 and sim.cold_starts == 2
    sim.now = 1.0          # sim time passes the reap window with no leases
    sim.submit(SimTask("a/map1/0", "a", 1.0, node=0))
    out = sim.run()
    # both expired warm workers were retired; the task cold-started fresh
    assert sim.reaped == 2 and sim.cold_starts == 3
    assert out["completion"]["a"] == pytest.approx(1.0 + 2.0 + 1.0)
